"""Benchmark smoke: every benchmarks/bench_*.py runs end to end at tiny sizes
with ``--json`` and emits a schema-valid payload (expected keys present, all
latencies finite) — so the BENCH_*.json producers can't silently rot between
the PRs that actually read their numbers. The same checkers also validate
every BENCH_*.json committed at the repo root, catching stale bench files
whose schema a later PR widened (e.g. the int8 quantized rows).

Marked ``bench_smoke`` and deselected from the fast tier (pytest.ini); CI runs
this in its own bench-smoke job (.github/workflows/ci.yml).
"""
import json
import math
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.bench_smoke

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# one entry per benchmark script: tiny-size args + the shape of its payload
# (a LIST of specs when one script emits several payload kinds — e.g.
# bench_knnlm.py's fig5 CSV mode and its fleet mode).
# kind 'csv' = the shared csv_row schema (rows of name/us_per_call/derived);
# the rest have bench-specific nested results, validated below.
BENCHES = {
    "bench_ablation.py": dict(
        args=["--tiny", "--requests", "1", "--retrievers", "edr",
              "--variants", ",p"], kind="csv"),
    "bench_batch_retrieval.py": dict(
        args=["--tiny", "--retrievers", "edr,adr,sr", "--sizes", "1,4",
              "--reps", "1"], kind="csv"),
    "bench_prefetch.py": dict(
        args=["--tiny", "--requests", "1", "--retrievers", "adr"], kind="csv"),
    "bench_serving.py": dict(
        args=["--tiny", "--requests", "1", "--retrievers", "sr"], kind="csv"),
    "bench_stride.py": dict(
        args=["--tiny", "--requests", "1", "--retrievers", "edr"], kind="csv"),
    "bench_knnlm.py": [
        dict(args=["--tiny", "--requests", "1", "--ks", "1"], kind="csv"),
        dict(args=["--tiny", "--mode", "fleet", "--concurrency", "1,2",
                   "--max-new", "8", "--k", "4"], kind="knnlm_fleet"),
    ],
    "bench_fleet.py": dict(
        args=["--retriever", "edr", "--concurrency", "1,2", "--requests", "2",
              "--max-new", "8", "--n-docs", "800"], kind="fleet"),
    "bench_continuous.py": dict(
        args=["--retriever", "edr", "--rates", "0", "--slots", "2",
              "--requests", "3", "--max-new", "8", "--n-docs", "800"],
        kind="continuous"),
    "bench_async_fleet.py": dict(
        args=["--retriever", "edr", "--concurrency", "2", "--requests", "2",
              "--max-new", "8", "--n-docs", "2000", "--enc-dim", "64",
              "--d-model", "64", "--wall-repeats", "1", "--shared-cache",
              "--kb-latency", "0.002"], kind="async_fleet"),
    "bench_backends.py": dict(
        args=["--kb-sizes", "256", "--batches", "1,2", "--k", "4",
              "--dim", "16", "--repeats", "1", "--mesh-shards", "2",
              "--retriever", "both", "--block-c", "128"], kind="backends"),
    "bench_shared_cache.py": dict(
        args=["--tiny", "--retriever", "edr"], kind="shared_cache"),
    "bench_faults.py": dict(
        args=["--retriever", "edr", "--rates", "0,0.3", "--slots", "2",
              "--requests", "3", "--max-new", "8", "--n-docs", "800"],
        kind="faults"),
}


def _finite(x):
    return isinstance(x, (int, float)) and math.isfinite(x)


def _check_csv(payload):
    rows = payload["rows"]
    assert rows, "no rows emitted"
    for r in rows:
        assert set(r) >= {"name", "us_per_call", "derived"}, r
        assert _finite(r["us_per_call"]) and r["us_per_call"] >= 0, r


def _check_fleet(payload):
    results = payload["results"]
    assert results, "no results emitted"
    for rows in results.values():
        assert rows
        for r in rows:
            assert set(r) >= {"concurrency", "tokps_modeled", "tokps_wall",
                              "latency_modeled_s", "kb_calls"}, r
            for key in ("tokps_modeled", "tokps_wall", "latency_modeled_s"):
                assert _finite(r[key]) and r[key] >= 0, (key, r)


def _check_continuous(payload):
    results = payload["results"]
    assert results, "no results emitted"
    for rows in results.values():
        assert rows
        for r in rows:
            assert set(r) >= {"rate", "continuous", "fixed"}, r
            for sched in ("continuous", "fixed"):
                cell = r[sched]
                assert set(cell) >= {"tokps_modeled", "tokps_wall", "p50_s",
                                     "p99_s", "makespan_s"}, cell
                assert all(_finite(v) and v >= 0 for v in cell.values()), cell


def _check_async_fleet(payload):
    results = payload["results"]
    assert results, "no results emitted"
    # the run's knobs are part of the committed record: a reader must be able
    # to tell whether the numbers include injected KB latency or the shared
    # cross-request cache tier
    cfg = payload["config"]
    assert "kb_latency_s" in cfg and _finite(cfg["kb_latency_s"]), cfg
    assert isinstance(cfg.get("shared_cache"), bool), cfg
    for levels in results.values():
        assert levels
        for cell in levels.values():
            assert set(cell) >= {"sync_modeled_s", "async_modeled_s",
                                 "modeled_speedup", "rounds", "kb_calls",
                                 "sync_wall_s", "async_wall_s", "wall_speedup",
                                 "verify_wall_s", "overlap_wall_s",
                                 "measured_overlap_s",
                                 "overlap_fraction"}, cell
            for key in ("sync_modeled_s", "async_modeled_s", "modeled_speedup",
                        "sync_wall_s", "async_wall_s", "wall_speedup",
                        "verify_wall_s", "overlap_wall_s",
                        "measured_overlap_s", "overlap_fraction"):
                assert _finite(cell[key]) and cell[key] >= 0, (key, cell)
            # the measured-overlap ledger's internal consistency: the span
            # intersection can't exceed either side
            assert cell["measured_overlap_s"] <= min(
                cell["verify_wall_s"], cell["overlap_wall_s"]) + 1e-9, cell


def _check_backends(payload):
    rows = payload["rows"]
    assert rows, "no rows emitted"
    for r in rows:
        assert set(r) >= {"backend", "retriever", "n_docs", "batch",
                          "seconds", "us_per_query", "exact", "recall_at_k",
                          "kb_bytes"}, r
        assert _finite(r["seconds"]) and r["seconds"] >= 0, r
        assert isinstance(r["exact"], bool), r
        assert r["exact"] is (not r["backend"].startswith("int8")), r
        assert _finite(r["recall_at_k"]) and 0 <= r["recall_at_k"] <= 1, r
        # exact backends are byte-parity vs the numpy reference scan; the
        # int8 family is held to the tested recall contract instead
        assert r["recall_at_k"] >= (0.99 if r["exact"] else 0.95), r
        assert isinstance(r["kb_bytes"], int) and r["kb_bytes"] > 0, r
        if r["retriever"] == "adr":
            # every ADR cell reports its candidate width and peak
            # candidate-buffer bytes, actual (fused/tiled) vs pre-gathered
            assert set(r) >= {"cand_width", "cand_buf_bytes",
                              "cand_buf_bytes_pregathered"}, r
            assert r["cand_width"] > 0, r
            assert r["cand_buf_bytes"] > 0, r
            assert r["cand_buf_bytes_pregathered"] > 0, r
            # the fused kernel/sharded families tile the gather: scratch is
            # at most ONE lane-aligned tile of per-candidate bytes
            # (fused_block_c: <= max(roundup(C, 128), 128) candidates wide —
            # at tiny C the 128-lane floor can exceed the tiny (B, C, ...)
            # slab, so the slab itself is only an upper bound at real widths;
            # the committed-file gate below demands >= 10x UNDER the slab at
            # C >= 4096)
            if r["backend"] in ("kernel", "sharded", "int8-kernel",
                                "int8-sharded"):
                lane_w = max(-(-r["cand_width"] // 128) * 128, 128)
                per_cand = r["cand_buf_bytes_pregathered"] // r["cand_width"]
                assert r["cand_buf_bytes"] <= per_cand * lane_w, r
    # the --retriever both sweep must cover the full backend x retriever grid
    cells = {(r["backend"], r["retriever"]) for r in rows}
    assert cells == {(b, a)
                     for b in ("numpy", "kernel", "sharded", "int8",
                               "int8-kernel", "int8-sharded")
                     for a in ("edr", "adr")}, cells
    # the int8 index is materially smaller than fp32 on the same KB
    # (1 byte/dim + 4 bytes/row of scale vs 4 bytes/dim: > 3x for d >= 16)
    by_kb = {(r["backend"], r["n_docs"]): r["kb_bytes"] for r in rows}
    for (b, n), nbytes in by_kb.items():
        if b == "int8":
            assert by_kb[("numpy", n)] / nbytes > 3, (n, nbytes)


def _check_shared_cache(payload):
    results = payload["results"]
    assert results, "no results emitted"
    for rows in results.values():
        assert rows
        for r in rows:
            assert set(r) >= {"rate", "off", "on", "outputs_identical"}, r
            assert r["outputs_identical"] is True, \
                "shared cache changed outputs"
            for mode in ("off", "on"):
                cell = r[mode]
                assert set(cell) >= {"p50_s", "p99_s", "makespan_s",
                                     "tokps_modeled", "kb_calls",
                                     "kb_queries", "merged_rows",
                                     "merged_rows_saved"}, cell
                for key in ("p50_s", "p99_s", "makespan_s", "tokps_modeled"):
                    assert _finite(cell[key]) and cell[key] >= 0, (key, cell)
            assert set(r["on"]) >= {"shared_hit_rate", "shared_hits_exact",
                                    "shared_hits_approx"}, r["on"]


def _check_faults(payload):
    results = payload["results"]
    assert results, "no results emitted"
    for rows in results.values():
        assert rows
        rates = [r["rate"] for r in rows]
        assert 0 in rates, "the sweep needs a fault-free reference rate"
        for r in rows:
            assert set(r) >= {"rate", "p50_s", "p99_s", "makespan_s",
                              "tokps_modeled", "goodput_modeled", "tokens_ok",
                              "degraded", "shed", "retried_errors",
                              "retried_timeouts", "failed_calls", "injected",
                              "outputs_match"}, r
            for key in ("p50_s", "p99_s", "makespan_s", "tokps_modeled",
                        "goodput_modeled"):
                assert _finite(r[key]) and r[key] >= 0, (key, r)
            # the preservation claim under chaos: every NON-degraded request
            # served byte-identical tokens to the fault-free reference run
            assert r["outputs_match"] is True, r
            assert r["goodput_modeled"] <= r["tokps_modeled"] + 1e-9, r
            if r["rate"] == 0:
                assert r["injected"] == 0 and r["degraded"] == 0, r


def _check_knnlm_fleet(payload):
    results = payload["results"]
    assert results, "no results emitted"
    cfg = payload["config"]
    assert {"concurrency", "k", "max_new", "stride"} <= set(cfg), cfg
    for modes in results.values():
        assert modes
        for levels in modes.values():
            assert levels
            for cell in levels.values():
                assert set(cell) >= {"seq_modeled_s", "fleet_modeled_s",
                                     "modeled_speedup", "tokps_modeled",
                                     "tokps_wall", "tokens", "kb_calls",
                                     "rounds"}, cell
                for key in ("seq_modeled_s", "fleet_modeled_s",
                            "modeled_speedup", "tokps_modeled", "tokps_wall"):
                    assert _finite(cell[key]) and cell[key] >= 0, (key, cell)
                # the Workload seam's preservation claim: every fleet-served
                # KNN-LM request token-matched its per-request KNNLMSeq run
                assert cell["outputs_token_match"] is True, cell
                assert cell["tokens"] > 0 and cell["kb_calls"] > 0, cell


CHECKS = dict(csv=_check_csv, fleet=_check_fleet, continuous=_check_continuous,
              async_fleet=_check_async_fleet, backends=_check_backends,
              shared_cache=_check_shared_cache, faults=_check_faults,
              knnlm_fleet=_check_knnlm_fleet)


def test_committed_bench_json_files_are_schema_valid():
    """Every BENCH_*.json committed at the repo root must still satisfy the
    schema its producer is held to — so a bench file can't silently go stale
    when a later PR widens the payload (e.g. the int8 rows adding
    exact/recall_at_k/kb_bytes to BENCH_backends.json)."""
    import glob
    committed = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    assert committed, "no committed BENCH_*.json at repo root"
    for path in committed:
        with open(path) as f:
            payload = json.load(f)
        kind = payload.get("bench")
        assert kind in CHECKS, (path, kind)
        CHECKS[kind](payload)
        if kind == "backends":
            # fused-gather acceptance on the COMMITTED sweep: at least one
            # kernel-family ADR cell probes C >= 4096 candidates, and there
            # the fused in-kernel gather holds >= 10x less candidate scratch
            # than the pre-gathered (B, C, ...) slab
            big = [r for r in payload["rows"]
                   if r["retriever"] == "adr" and r.get("cand_width", 0) >= 4096
                   and r["backend"] in ("kernel", "sharded", "int8-kernel",
                                        "int8-sharded")]
            assert big, f"{path}: no kernel-family ADR cell with C >= 4096"
            for r in big:
                assert r["cand_buf_bytes"] * 10 \
                    <= r["cand_buf_bytes_pregathered"], \
                    (path, r["backend"], r["cand_width"])
        if kind == "async_fleet":
            # wall-clock acceptance on the COMMITTED run: EDR at c=4 shows a
            # MEASURED (median wall) speedup > 1.0 and real measured overlap
            cell = payload["results"]["edr"]["4"]
            assert cell["wall_speedup"] > 1.0, cell["wall_speedup"]
            assert cell["measured_overlap_s"] > 0, cell
        if kind == "knnlm_fleet":
            # Workload-seam acceptance on the COMMITTED run: fleet-served
            # KNN-LM beats per-request KNNLMSeq by >= 1.5x modeled on the
            # EDR cell at concurrency >= 4
            big = {int(c): cell
                   for c, cell in payload["results"]["edr"]["fleet"].items()
                   if int(c) >= 4}
            assert big, f"{path}: no EDR fleet cell at concurrency >= 4"
            for c, cell in big.items():
                assert cell["modeled_speedup"] >= 1.5, (c, cell)


def test_every_bench_script_has_a_smoke_entry():
    scripts = sorted(f for f in os.listdir(os.path.join(ROOT, "benchmarks"))
                     if f.startswith("bench_") and f.endswith(".py"))
    assert scripts == sorted(BENCHES), \
        "new bench_*.py without a smoke entry (or a stale entry here)"


def _specs(script):
    v = BENCHES[script]
    return v if isinstance(v, list) else [v]


@pytest.mark.parametrize("script,spec", [
    pytest.param(s, spec, id=f"{s}-{spec['kind']}")
    for s in sorted(BENCHES) for spec in _specs(s)])
def test_bench_runs_and_emits_valid_json(script, spec, tmp_path):
    out = tmp_path / "out.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join("benchmarks", script),
         *spec["args"], "--json", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=570)
    assert proc.returncode == 0, \
        f"{script} failed:\n{proc.stdout[-1500:]}\n{proc.stderr[-3000:]}"
    assert out.exists(), f"{script} did not write --json output"
    payload = json.loads(out.read_text())
    assert payload.get("bench"), payload.keys()
    CHECKS[spec["kind"]](payload)
