"""MoE layer: dispatch correctness, capacity semantics, determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as MOE

CFG = reduced(get_config("qwen2-moe-a2.7b"))


def test_capacity_matches_exact_when_capacity_ample():
    """With capacity_factor high enough to avoid drops, the capacity-dispatch path
    must equal the dropless path exactly."""
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=8.0))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    y_cap, aux1 = MOE.apply_moe(p, cfg, x)
    y_ex, aux2 = MOE.apply_moe_exact(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_ex), atol=1e-4,
                               rtol=1e-3)


def test_chunking_invariance():
    cfg1 = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, dispatch_chunk=8,
                                     capacity_factor=8.0))
    cfg2 = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, dispatch_chunk=64,
                                     capacity_factor=8.0))
    p = MOE.init_moe(jax.random.PRNGKey(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, CFG.d_model)) * 0.3
    y1, _ = MOE.apply_moe(p, cfg1, x)
    y2, _ = MOE.apply_moe(p, cfg2, x)
    # chunked capacity differs per chunk; with ample capacity results agree
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)


def test_capacity_drops_tokens_when_overloaded():
    """With capacity_factor << 1 the routed output must differ from dropless
    (drops actually happen) yet remain finite."""
    cfg = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=0.2))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    y_cap, _ = MOE.apply_moe(p, cfg, x)
    y_ex, _ = MOE.apply_moe_exact(p, cfg, x)
    assert bool(jnp.isfinite(y_cap).all())
    assert not np.allclose(np.asarray(y_cap), np.asarray(y_ex), atol=1e-5)


def test_router_determinism_and_aux_finite():
    p = MOE.init_moe(jax.random.PRNGKey(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, CFG.d_model))
    y1, a1 = MOE.apply_moe_exact(p, CFG, x)
    y2, a2 = MOE.apply_moe_exact(p, CFG, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert bool(jnp.isfinite(a1)) and float(a1) >= 0


def test_shared_experts_always_active():
    """Zeroing the router must keep the shared-expert contribution."""
    p = MOE.init_moe(jax.random.PRNGKey(0), CFG, jnp.float32)
    assert CFG.moe.num_shared_experts >= 1
    p0 = dict(p, router=jnp.zeros_like(p["router"]),
              w_gate=jnp.zeros_like(p["w_gate"]),
              w_up=jnp.zeros_like(p["w_up"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, CFG.d_model))
    y, _ = MOE.apply_moe_exact(p0, CFG, x)
    assert float(jnp.abs(y).max()) > 0
