"""Retriever correctness + the batched-retrieval property the paper's saving rests
on (§A.1): batched results identical to sequential, batched latency sublinear."""
import numpy as np
import pytest

from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def corpus():
    docs = synthetic_corpus(3000, 1024)
    enc = ContextEncoder(1024, d=32)
    return docs, enc, DenseKB.build(docs, enc), SparseKB.build(docs)


def test_edr_is_exact(corpus):
    docs, enc, dkb, _ = corpus
    r = ExactDenseRetriever(dkb)
    q = enc.encode(docs[11][:10])
    ids, scores = r.retrieve(q[None], 10)
    brute = dkb.embeddings @ q
    expect = np.argsort(-brute, kind="stable")[:10]
    assert set(ids[0]) == set(expect)
    np.testing.assert_allclose(np.sort(scores[0])[::-1],
                               np.sort(brute[expect])[::-1], atol=1e-5)


def test_ivf_recall_reasonable(corpus):
    docs, enc, dkb, _ = corpus
    exact = ExactDenseRetriever(dkb)
    approx = IVFRetriever(dkb, n_clusters=32, nprobe=4)
    qs = enc.encode_batch([d[:10] for d in docs[:50]])
    ei, _ = exact.retrieve(qs, 5)
    ai, _ = approx.retrieve(qs, 5)
    recall = np.mean([len(set(a) & set(e)) / 5 for a, e in zip(ai, ei)])
    assert recall > 0.5, f"IVF recall too low: {recall}"


def test_ivf_less_accurate_than_exact(corpus):
    """The ADR must actually be approximate (the paper's trade-off axis)."""
    docs, enc, dkb, _ = corpus
    exact = ExactDenseRetriever(dkb)
    approx = IVFRetriever(dkb, n_clusters=64, nprobe=1)
    qs = enc.encode_batch([d[:10] for d in docs[::37]])
    ei, _ = exact.retrieve(qs, 1)
    ai, _ = approx.retrieve(qs, 1)
    agree = np.mean(ei[:, 0] == ai[:, 0])
    assert agree < 1.0


def test_bm25_ranks_term_matches_first(corpus):
    docs, _, _, skb = corpus
    r = BM25Retriever(skb)
    query = docs[42][:8]
    ids, scores = r.retrieve([query], 5)
    assert scores[0, 0] > 0
    top_doc = set(docs[int(ids[0, 0])])
    assert len(top_doc & set(query)) >= 1


@pytest.mark.parametrize("which", ["edr", "sr"])
def test_batched_equals_sequential(corpus, which):
    docs, enc, dkb, skb = corpus
    if which == "edr":
        r = ExactDenseRetriever(dkb)
        qs = [enc.encode(d[:10]) for d in docs[:8]]
        bi, bs = r.retrieve(np.stack(qs), 4)
        for i, q in enumerate(qs):
            si, ss = r.retrieve(q[None], 4)
            assert list(si[0]) == list(bi[i])
    else:
        r = BM25Retriever(skb)
        qs = [d[:6] for d in docs[:8]]
        bi, bs = r.retrieve(qs, 4)
        for i, q in enumerate(qs):
            si, ss = r.retrieve([q], 4)
            assert list(si[0]) == list(bi[i])


def test_knn_datastore_consecutive_entries(corpus):
    docs, enc, _, _ = corpus
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs[:100]])
    ds = build_knn_datastore(stream, enc, context=8, limit=500)
    assert ds.size == 500
    assert ds.values is not None
    # entry i's value is the token following entry i's context window
    assert int(ds.values[3]) == int(stream[3 + 8])


def test_batched_retrieval_latency_sublinear(corpus):
    """Paper §A.1: one batch-16 call is cheaper than 16 sequential calls (EDR).
    Median of 3 repetitions + margin — single-core wall timing is noisy."""
    import time
    docs, enc, dkb, _ = corpus
    r = ExactDenseRetriever(dkb)
    qs = enc.encode_batch([d[:10] for d in docs[:64]])
    r.retrieve(qs, 4)  # warm
    seqs, bats = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        for i in range(16):
            r.retrieve(qs[i:i + 1], 4)
        seqs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        r.retrieve(qs[:16], 4)
        bats.append(time.perf_counter() - t0)
    t_seq, t_bat = sorted(seqs)[1], sorted(bats)[1]
    assert t_bat < t_seq * 1.2, \
        f"batched {t_bat:.4f}s not cheaper than sequential {t_seq:.4f}s"
