"""Retriever correctness + the batched-retrieval property the paper's saving rests
on (§A.1): batched results identical to sequential, batched latency sublinear."""
import numpy as np
import pytest

from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def corpus():
    docs = synthetic_corpus(3000, 1024)
    enc = ContextEncoder(1024, d=32)
    return docs, enc, DenseKB.build(docs, enc), SparseKB.build(docs)


def test_edr_is_exact(corpus):
    docs, enc, dkb, _ = corpus
    r = ExactDenseRetriever(dkb)
    q = enc.encode(docs[11][:10])
    ids, scores = r.retrieve(q[None], 10)
    brute = dkb.embeddings @ q
    expect = np.argsort(-brute, kind="stable")[:10]
    assert set(ids[0]) == set(expect)
    np.testing.assert_allclose(np.sort(scores[0])[::-1],
                               np.sort(brute[expect])[::-1], atol=1e-5)


def test_ivf_recall_reasonable(corpus):
    docs, enc, dkb, _ = corpus
    exact = ExactDenseRetriever(dkb)
    approx = IVFRetriever(dkb, n_clusters=32, nprobe=4)
    qs = enc.encode_batch([d[:10] for d in docs[:50]])
    ei, _ = exact.retrieve(qs, 5)
    ai, _ = approx.retrieve(qs, 5)
    recall = np.mean([len(set(a) & set(e)) / 5 for a, e in zip(ai, ei)])
    assert recall > 0.5, f"IVF recall too low: {recall}"


def test_ivf_less_accurate_than_exact(corpus):
    """The ADR must actually be approximate (the paper's trade-off axis)."""
    docs, enc, dkb, _ = corpus
    exact = ExactDenseRetriever(dkb)
    approx = IVFRetriever(dkb, n_clusters=64, nprobe=1)
    qs = enc.encode_batch([d[:10] for d in docs[::37]])
    ei, _ = exact.retrieve(qs, 1)
    ai, _ = approx.retrieve(qs, 1)
    agree = np.mean(ei[:, 0] == ai[:, 0])
    assert agree < 1.0


def test_bm25_ranks_term_matches_first(corpus):
    docs, _, _, skb = corpus
    r = BM25Retriever(skb)
    query = docs[42][:8]
    ids, scores = r.retrieve([query], 5)
    assert scores[0, 0] > 0
    top_doc = set(docs[int(ids[0, 0])])
    assert len(top_doc & set(query)) >= 1


@pytest.mark.parametrize("which", ["edr", "adr", "sr"])
def test_batched_equals_sequential(corpus, which):
    docs, enc, dkb, skb = corpus
    if which == "edr":
        r = ExactDenseRetriever(dkb)
        qs = [enc.encode(d[:10]) for d in docs[:8]]
        bi, bs = r.retrieve(np.stack(qs), 4)
        for i, q in enumerate(qs):
            si, ss = r.retrieve(q[None], 4)
            assert list(si[0]) == list(bi[i])
    elif which == "adr":
        # the vectorized probe's padded shape is fixed by the index, so a
        # batched call is byte-identical (ids AND scores) to one-at-a-time
        r = IVFRetriever(dkb, n_clusters=32, nprobe=4)
        qs = [enc.encode(d[:10]) for d in docs[:8]]
        bi, bs = r.retrieve(np.stack(qs), 4)
        for i, q in enumerate(qs):
            si, ss = r.retrieve(q[None], 4)
            assert list(si[0]) == list(bi[i])
            assert np.array_equal(ss[0], bs[i])
    else:
        r = BM25Retriever(skb)
        qs = [d[:6] for d in docs[:8]]
        bi, bs = r.retrieve(qs, 4)
        for i, q in enumerate(qs):
            si, ss = r.retrieve([q], 4)
            assert list(si[0]) == list(bi[i])


def _ivf_reference_loop(r, queries, k):
    """The scalar IVFRetriever.retrieve: per-query candidate concatenation +
    GEMV + partial sort, kept as the parity oracle. Candidates are sorted by
    id before scoring — the canonical (score desc, id asc) tie order every
    execution backend produces (a stable sort over id-ascending candidates
    breaks score ties by id)."""
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    cs = np.argsort(-(queries @ r.centroids.T), axis=1)[:, :r.nprobe]
    all_ids, all_scores = [], []
    for qi in range(queries.shape[0]):
        cand = np.sort(np.concatenate([r.buckets[c] for c in cs[qi]]))
        if cand.size == 0:
            cand = np.arange(min(k, r.kb.size))
        s = r.kb.embeddings[cand] @ queries[qi]
        kk = min(k, cand.size)
        top = np.argpartition(-s, kth=kk - 1)[:kk]
        top = top[np.argsort(-s[top], kind="stable")]
        ids = cand[top]
        sc = s[top]
        if kk < k:
            ids = np.pad(ids, (0, k - kk), constant_values=ids[-1])
            sc = np.pad(sc, (0, k - kk), constant_values=sc[-1])
        all_ids.append(ids)
        all_scores.append(sc)
    return np.stack(all_ids).astype(np.int64), np.stack(all_scores)


@pytest.mark.parametrize("k", [1, 5, 40])
def test_ivf_vectorized_matches_reference_loop(corpus, k):
    """The vectorized probe (padded gather + batched matmul) returns the
    reference loop's exact ids — including padding semantics for rows with
    fewer than k candidates — and its scores to BLAS-kernel precision (the
    batched matmul and the per-query GEMV may round differently in the last
    ulp; tie order within equal scores is canonical in both)."""
    docs, enc, dkb, _ = corpus
    for nprobe in (1, 4):
        r = IVFRetriever(dkb, n_clusters=64, nprobe=nprobe)
        qs = enc.encode_batch([d[:10] for d in docs[:32]])
        vi, vs = r.retrieve(qs, k)
        ri, rs = _ivf_reference_loop(r, qs, k)
        assert vi.shape == (32, k) and vs.dtype == np.float32
        assert np.array_equal(vi, ri), f"nprobe={nprobe}: ids diverged"
        np.testing.assert_allclose(vs, rs, atol=1e-5)


def test_sparse_score_dedupes_repeated_terms(corpus):
    """SparseKB.score computes every unique term's tf column in one pass but
    must stay float-exact with the per-occurrence scalar loop — including
    repeated query terms (each occurrence contributes once, in order) and
    unknown terms (skipped)."""
    docs, _, _, skb = corpus
    rng = np.random.default_rng(7)
    for trial in range(10):
        q = rng.integers(0, 1100, size=int(rng.integers(1, 30))).tolist()
        q = q + q[: max(1, len(q) // 2)] + [10 ** 9]   # repeats + unknown
        T, dl = skb.terms, skb.doc_len
        norm = skb.k1 * (1 - skb.b + skb.b * dl / skb.avgdl)
        want = np.zeros(T.shape[0], np.float32)
        for t in q:
            idf = skb.idf.get(int(t))
            if idf is None:
                continue
            tf = (T == int(t)).sum(1).astype(np.float32)
            want += idf * tf * (skb.k1 + 1) / (tf + norm)
        got = skb.score(q)
        assert np.array_equal(got, want), f"trial {trial} diverged"


def test_retriever_stats_thread_safe(corpus):
    """With async fleet rounds the worker thread calls stats.add while the
    main thread reads model_latency — hammer both concurrently and check the
    counters never tear."""
    import threading
    from repro.retrieval.retrievers import RetrieverStats
    stats = RetrieverStats("const")
    N, T = 500, 4

    def writer():
        for _ in range(N):
            stats.add(1, 1e-3)

    def reader():
        for _ in range(N):
            assert stats.model_latency(8) >= 0.0

    threads = [threading.Thread(target=writer) for _ in range(T)] + \
              [threading.Thread(target=reader) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.calls == N * T and stats.queries == N * T
    assert abs(stats.time - N * T * 1e-3) < 1e-6
    assert abs(stats.model_latency(1) - 1e-3) < 1e-9


def test_knn_datastore_consecutive_entries(corpus):
    docs, enc, _, _ = corpus
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs[:100]])
    ds = build_knn_datastore(stream, enc, context=8, limit=500)
    assert ds.size == 500
    assert ds.values is not None
    # entry i's value is the token following entry i's context window
    assert int(ds.values[3]) == int(stream[3 + 8])


def test_batched_retrieval_latency_sublinear(corpus):
    """Paper §A.1: one batch-16 call is cheaper than 16 sequential calls (EDR).
    Median of 3 repetitions + margin — single-core wall timing is noisy."""
    import time
    docs, enc, dkb, _ = corpus
    r = ExactDenseRetriever(dkb)
    qs = enc.encode_batch([d[:10] for d in docs[:64]])
    r.retrieve(qs, 4)  # warm
    seqs, bats = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        for i in range(16):
            r.retrieve(qs[i:i + 1], 4)
        seqs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        r.retrieve(qs[:16], 4)
        bats.append(time.perf_counter() - t0)
    t_seq, t_bat = sorted(seqs)[1], sorted(bats)[1]
    assert t_bat < t_seq * 1.2, \
        f"batched {t_bat:.4f}s not cheaper than sequential {t_seq:.4f}s"
