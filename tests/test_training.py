"""Training substrate: optimizer math, microbatch equivalence, loss descent,
checkpoint round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import SyntheticLM, synthetic_corpus
from repro.training.optimizer import (AdamWConfig, adamw_update, cosine_schedule,
                                      global_norm, init_adamw)
from repro.training.trainer import make_train_step


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    sched = cosine_schedule(cfg)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert np.isclose(float(sched(jnp.asarray(10))), 1e-3, rtol=1e-3)
    assert float(sched(jnp.asarray(100))) >= 1e-4 * 0.99
    assert float(sched(jnp.asarray(55))) < 1e-3


def test_adamw_moves_toward_gradient():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    st = init_adamw(params)
    new, st, m = adamw_update(cfg, grads, st, params)
    assert float(new["w"].mean()) < 1.0
    assert int(st.step) == 1


def test_grad_clip_caps_global_norm():
    cfg = AdamWConfig(lr=1e-9, grad_clip=1.0)
    params = {"w": jnp.zeros((8,))}
    grads = {"w": jnp.full((8,), 100.0)}
    _, _, m = adamw_update(cfg, grads, init_adamw(params), params)
    assert float(m["grad_norm"]) > 1.0  # reported raw norm


def test_microbatch_accumulation_equivalence():
    cfg = reduced(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    data = SyntheticLM(cfg.vocab_size, 32, 8).batch(0)
    s1 = jax.jit(make_train_step(model, opt_cfg, num_microbatches=1))
    s4 = jax.jit(make_train_step(model, opt_cfg, num_microbatches=4))
    p1, _, m1 = s1(params, init_adamw(params), data)
    p4, _, m4 = s4(params, init_adamw(params), data)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5, f"microbatched params diverged by {d}"


def test_loss_decreases_on_synthetic_data():
    cfg = reduced(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticLM(cfg.vocab_size, 64, 8)
    opt = init_adamw(params)
    losses = []
    for i in range(40):
        params, opt, m = step(params, opt, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_checkpoint_roundtrip():
    cfg = reduced(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    opt = init_adamw(params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, params, opt, extra={"note": "t"})
        assert latest_step(d) == 7
        p2, o2, manifest = restore_checkpoint(d, 7, params, opt)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert manifest["extra"]["note"] == "t"


def test_synthetic_corpus_topical_locality():
    """Docs in the same topic overlap more than cross-topic (the locality the cache
    exploits)."""
    docs = synthetic_corpus(100, 1024, n_topics=4)
    same = len(set(docs[0]) & set(docs[1]))
    cross = len(set(docs[0]) & set(docs[99]))
    assert same > cross
