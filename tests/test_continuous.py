"""Output preservation under continuous batching — the paper's central claim
must survive slot churn:

  (a) server level: ContinuousFleetServer outputs are byte-identical to
      per-request RaLMSeq for EDR/ADR/SR under staggered admissions,
      heterogeneous per-request budgets, and slot reuse,
  (b) engine level: admitting a request mid-flight — including between a
      sibling slot's speculation snapshot and its rollback restore — never
      perturbs that sibling, and a retired slot is cleanly reusable,
  (c) property-style: random arrival orders/offsets never change any
      request's tokens,
  (d) the KB-call merge invariant: one batched verification call per round,
      with admission seeding riding along (dedicated seed calls only when no
      round precedes the admission wave).

Engines are module-scoped (serve()/start() reset them) so jit caches are
shared across tests — the fast tier pays each prefill shape once.
"""
import dataclasses
import random

import jax
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSeq
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, Request, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 5)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 2, cache_window=256)
    return model, params, docs, enc, dkb, skb, prompts, seng, beng


RCFG = RaLMConfig(max_new_tokens=20, speculation_stride=3)
# 5 requests through 2 slots: forces queueing, staggered mid-flight admission,
# and slot reuse; heterogeneous budgets force slots to free at different times
BUDGETS = [20, 8, 14, 20, 6]


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


def _seq_tokens(seng, retr, enc, rcfg, prompt, budget):
    one = dataclasses.replace(rcfg, max_new_tokens=budget)
    return RaLMSeq(seng, retr, one, enc).serve(prompt).tokens


def _clear(beng):
    for b in range(beng.n_slots):
        if beng.active[b]:
            beng.retire(b)


# ---------------------------------------------------------------------------------
# (a) server level: continuous batching == per-request RaLMSeq, every retriever
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
def test_continuous_output_preservation(stack, retr_name):
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = _retriever(retr_name, dkb, skb)
    seq = [_seq_tokens(seng, retr, enc, RCFG, p, mn)
           for p, mn in zip(prompts, BUDGETS)]
    server = ContinuousFleetServer(beng, retr, RCFG, enc)
    cr = server.serve(as_requests(prompts, max_new=BUDGETS))
    assert cr.max_live == beng.n_slots  # 5 requests really shared 2 slots
    for i, r in enumerate(cr.results):
        assert r.tokens == seq[i], f"{retr_name}: request {i} diverged"
        assert len(r.tokens) == BUDGETS[i]


def test_continuous_preserves_under_forced_rollbacks(stack):
    """Capacity-1 cache: every slot mis-speculates and rolls back repeatedly
    while admissions churn around them — outputs must still match RaLMSeq
    (this is the server-level admit-during-a-neighbor's-rollback case)."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, cache_capacity=1)
    seq = [_seq_tokens(seng, retr, enc, rcfg, p, mn)
           for p, mn in zip(prompts, BUDGETS)]
    cr = ContinuousFleetServer(beng, retr, rcfg, enc).serve(
        as_requests(prompts, max_new=BUDGETS))
    assert sum(r.mismatches for r in cr.results) > 0, \
        "capacity-1 cache should force mis-speculation"
    for i, r in enumerate(cr.results):
        assert r.tokens == seq[i], f"request {i} perturbed by churn+rollback"


def test_continuous_matches_fixed_fleet_group(stack):
    """With exactly n_slots requests all arriving at t=0 and uniform budgets,
    continuous degenerates to the fixed fleet: same tokens."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    fr = FleetServer(beng, retr, RCFG, enc).serve(prompts[:2])
    cr = ContinuousFleetServer(beng, retr, RCFG, enc).serve(
        as_requests(prompts[:2]))
    assert [r.tokens for r in cr.results] == [r.tokens for r in fr.results]


# ---------------------------------------------------------------------------------
# (b) engine level: mid-flight admit / retire / slot reuse
# ---------------------------------------------------------------------------------
def test_admit_during_sibling_rollback(stack):
    """Admit into a free slot BETWEEN a sibling's speculation snapshot and its
    rollback restore — the most adversarial interleaving continuous batching
    produces. Both slots must decode exactly like single-request engines."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    _clear(beng)
    beng.admit(0, [5, 6, 7, 8])
    beng.gen([0], [3])
    snap = beng.snapshot(0)
    beng.set_doc(0, (2, 3, 4))          # slot 0 speculates: doc swap + stride
    beng.gen([0], [4])
    beng.admit(1, [40, 41, 42, 43])     # admission lands mid-speculation
    beng.gen([0, 1], [2, 2])
    beng.restore(0, snap)               # slot 0 mis-speculated: roll back
    cont = beng.gen([0, 1], [3, 3])
    seng.start([5, 6, 7, 8])
    seng.gen(3)
    assert seng.gen(3) == cont[0], "rolled-back slot diverged"
    seng.start([40, 41, 42, 43])
    first = seng.gen(2)
    assert first + seng.gen(3) == beng.generated(1), \
        "slot admitted mid-speculation diverged"


def test_slot_reuse_after_retire(stack):
    """A retired slot must be indistinguishable from a fresh one: the next
    request admitted into it decodes exactly like a single-request engine,
    and the surviving sibling is untouched by the retire/admit cycle."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    _clear(beng)
    beng.admit(0, [5, 6, 7, 8])
    beng.admit(1, [40, 41, 42, 43])
    first = beng.gen([0, 1], [4, 2])
    beng.retire(1)
    assert beng.free_slots() == [1]
    beng.admit(1, [9, 10, 11])          # reuse the freed slot mid-flight
    second = beng.gen([0, 1], [2, 5])
    seng.start([5, 6, 7, 8])
    assert seng.gen(4) == first[0] and seng.gen(2) == second[0]
    seng.start([9, 10, 11])
    assert seng.gen(5) == second[1], "reused slot inherited stale state"


def test_lifecycle_guards(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    _clear(beng)
    beng.admit(0, [5, 6, 7])
    with pytest.raises(AssertionError):
        beng.admit(0, [1, 2, 3])        # double admit
    with pytest.raises(AssertionError):
        beng.retire(1)                  # retire an idle slot
    with pytest.raises(AssertionError):
        beng.gen([0, 1], [2, 2])        # gen over an idle slot
    beng.retire(0)


# ---------------------------------------------------------------------------------
# (c) property: random arrival orders never change any request's tokens
# ---------------------------------------------------------------------------------
def test_random_arrival_orders_preserve_outputs(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    budgets = BUDGETS[:4]
    seq = [_seq_tokens(seng, retr, enc, RCFG, p, mn)
           for p, mn in zip(prompts[:4], budgets)]
    server = ContinuousFleetServer(beng, retr, RCFG, enc)
    for trial in range(3):
        rng = random.Random(trial)
        reqs = [Request(rid=i, prompt=prompts[i],
                        arrival=rng.random() * 0.02 * trial,
                        max_new=budgets[i]) for i in range(4)]
        rng.shuffle(reqs)               # submission order != rid order
        cr = server.serve(reqs)
        for i, r in enumerate(cr.results):
            assert r.tokens == seq[i], \
                f"trial {trial}: request {i} depends on arrival order"


# ---------------------------------------------------------------------------------
# (d) KB-call merge invariant under churn
# ---------------------------------------------------------------------------------
def test_one_verification_call_per_round(stack):
    """Cross-request batched verification survives churn: every round is ONE
    KB call, admission seeding rides along existing calls, and only waves
    with no preceding round (here: the initial one) pay a dedicated call."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    server = ContinuousFleetServer(beng, retr, RCFG, enc)
    cr = server.serve(as_requests(prompts, max_new=BUDGETS))
    assert cr.kb_calls == cr.rounds + cr.seed_calls
    assert cr.seed_calls == 1, "later admissions should be pre-seeded"
    # timed arrivals: requests landing mid-round ride the round's verification
    # call too (it is issued after the speculation phase, which takes far
    # longer than these offsets on any machine) — still one dedicated call
    cr = server.serve(as_requests(prompts, arrivals=[0, 0, 1e-4, 2e-4, 3e-4],
                                  max_new=BUDGETS))
    assert cr.kb_calls == cr.rounds + cr.seed_calls
    assert cr.seed_calls == 1, "mid-round arrivals should be pre-seeded"
