"""Docs stay true: README/docs exist, every command they show references real
entry points, and the serving drivers' CLIs actually parse (--help smoke).
Fast tier — CI runs this in its docs job too (.github/workflows/ci.yml).
"""
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOC_FILES = ["README.md", "docs/architecture.md", "docs/benchmarks.md"]


def _doc_commands():
    """Every `python ...` command inside a fenced code block of the docs."""
    cmds = []
    for rel in DOC_FILES:
        text = open(os.path.join(ROOT, rel)).read()
        for block in re.findall(r"```(?:\w*\n)?(.*?)```", text, re.S):
            for line in block.splitlines():
                line = line.strip()
                if re.match(r"(PYTHONPATH=\S+\s+)?python\s", line):
                    cmds.append((rel, line))
    return cmds


def test_docs_exist():
    for rel in DOC_FILES:
        assert os.path.exists(os.path.join(ROOT, rel)), f"{rel} missing"


def test_doc_commands_reference_real_entry_points():
    cmds = _doc_commands()
    assert len(cmds) >= 8, "docs lost their runnable examples"
    for rel, cmd in cmds:
        m = re.search(r"-m\s+([\w.]+)", cmd)
        if m and m.group(1).split(".")[0] in ("repro", "benchmarks"):
            mod = m.group(1)
            path = (os.path.join(ROOT, "src", *mod.split("."))
                    if mod.startswith("repro") else
                    os.path.join(ROOT, *mod.split(".")))
            assert (os.path.exists(path + ".py")
                    or os.path.isdir(path)), f"{rel}: no module {mod} ({cmd})"
        for script in re.findall(r"(?:benchmarks|examples)/\w+\.py", cmd):
            assert os.path.exists(os.path.join(ROOT, script)), \
                f"{rel}: no script {script} ({cmd})"


@pytest.mark.parametrize("target", [
    ["-m", "repro.launch.serve"],
    ["benchmarks/bench_continuous.py"],
    ["benchmarks/bench_fleet.py"],
    ["benchmarks/bench_async_fleet.py"],
    ["benchmarks/bench_backends.py"],
])
def test_cli_help_smoke(target):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, *target, "--help"], cwd=ROOT,
                         env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, f"{target} --help failed:\n{out.stderr[-2000:]}"
    assert "usage" in out.stdout.lower()
