"""OS^3 scheduler: objective math (appendix A.2) + adaptive behaviour properties."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.scheduler import OS3, expected_verified, objective


@given(st.floats(0.0, 0.999), st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_expected_verified_formula(gamma, s):
    """Closed form == direct expectation sum (paper A.2 derivation)."""
    direct = sum(gamma ** i for i in range(s))
    assert math.isclose(expected_verified(gamma, s), direct, rel_tol=1e-9)


@given(st.floats(0.05, 0.6), st.floats(1e-4, 1e-1), st.floats(1e-4, 1e-1))
@settings(max_examples=60, deadline=None)
def test_async_objective_dominates_sync(gamma, a, b):
    """Ideal async latency <= sync latency for every stride => objective >=."""
    for s in range(1, 9):
        assert objective(gamma, s, a, b, True) >= objective(gamma, s, a, b, False) - 1e-12


def test_expensive_retrieval_prefers_larger_stride():
    """Paper §A.4: EDR (b >> a) wants large s; cheap retrievers want small s."""
    sch = OS3(max_stride=16)
    s_cheap = sch.optimal_stride(gamma=0.6, a=1.0, b=0.01)
    s_exp = sch.optimal_stride(gamma=0.6, a=0.01, b=1.0)
    assert s_exp > s_cheap
    assert s_cheap == 1


def test_async_with_b_less_than_a_prefers_stride_1():
    """Paper §3: with async verification and b <= a, s=1 is optimal."""
    sch = OS3(max_stride=16, async_mode=True)
    assert sch.optimal_stride(gamma=0.5, a=1.0, b=0.5) == 1


def test_gamma_mle_estimation():
    sch = OS3(window=5, gamma_max=0.9)
    # 3 rounds of stride 4: matches 4 (full), 2 (fail), 4 (full)
    sch.record_verification(0.1, 4, 4)
    sch.record_verification(0.1, 4, 2)
    sch.record_verification(0.1, 4, 4)
    # num = 10 matches; fails = 1 round with M < s  -> 10/11
    assert math.isclose(sch.gamma, min(10 / 11, 0.9), rel_tol=1e-9)


def test_gamma_capped():
    sch = OS3(window=5, gamma_max=0.6)
    for _ in range(5):
        sch.record_verification(0.1, 4, 4)      # perfect speculation
    assert sch.gamma == 0.6                     # capped, no division blow-up


def test_scheduler_adapts_stride_upward_when_accurate():
    sch = OS3(window=5, gamma_max=0.6, max_stride=16)
    sch.record_speculation(0.01)                # a small
    sch.record_verification(1.0, 1, 1)          # b large, success
    s1 = sch.stride
    for _ in range(4):
        sch.record_speculation(0.01)
        sch.record_verification(1.0, sch.stride, sch.stride)
    assert sch.stride >= s1 and sch.stride > 1


@given(st.floats(0.0, 0.6), st.floats(1e-4, 1.0), st.floats(1e-4, 1.0),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_optimal_stride_bounds(gamma, a, b, async_mode):
    sch = OS3(max_stride=16, async_mode=async_mode)
    s = sch.optimal_stride(gamma=gamma, a=a, b=b)
    assert 1 <= s <= 16
