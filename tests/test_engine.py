"""Serving engine: snapshot/rollback exactness, ring-buffer window semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import layers as L
from repro.models.model import build_model
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_snapshot_rollback_exact(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, cache_window=128)
    eng.start([5, 6, 7, 8])
    eng.gen(4)
    snap = eng.snapshot()
    branch_a = eng.gen(6)
    eng.restore(snap)
    branch_b = eng.gen(6)
    assert branch_a == branch_b
    assert eng.tokens[-6:] == branch_b


def test_set_doc_changes_conditioning(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, cache_window=128)
    eng.start([5, 6, 7, 8], doc=(1, 2, 3))
    a = eng.gen(4)
    eng2 = ServeEngine(model, params, cache_window=128)
    eng2.start([5, 6, 7, 8], doc=(9, 10, 11))
    b = eng2.gen(4)
    assert a != b or True  # docs usually change outputs; never crash
    # deterministic given same doc
    eng3 = ServeEngine(model, params, cache_window=128)
    eng3.start([5, 6, 7, 8], doc=(1, 2, 3))
    assert eng3.gen(4) == a


def test_ring_buffer_sliding_window_semantics(setup):
    """Writing past W must attend over exactly the last W positions (incl. self):
    decode attention over a wrapped ring == plain attention with window W."""
    cfg, model, params = setup
    key = jax.random.PRNGKey(1)
    mixer = model._layer_params(params, 0)["mixer"]
    W, steps = 16, 40
    B, KV, hd = 1, cfg.num_kv_heads, cfg.head_dim
    xs = jax.random.normal(key, (B, steps, cfg.d_model)) * 0.5

    k_cache = jnp.zeros((B, W, KV, hd))
    v_cache = jnp.zeros((B, W, KV, hd))
    outs = []
    for t in range(steps):
        pos = jnp.int32(t)
        write = (pos % W).astype(jnp.int32)
        clen = jnp.minimum(pos + 1, W)
        o, k_cache, v_cache = L.apply_self_attention_decode(
            mixer, cfg, xs[:, t:t + 1], pos, k_cache, v_cache, clen, write)
        outs.append(o)
    ring_out = jnp.concatenate(outs, axis=1)
    full_out = L.apply_self_attention(mixer, cfg, xs,
                                      jnp.arange(steps)[None], causal=True,
                                      window=W)
    np.testing.assert_allclose(np.asarray(ring_out[:, -1]),
                               np.asarray(full_out[:, -1]), atol=2e-4, rtol=2e-3)


def test_blockwise_attention_matches_plain(setup):
    cfg, _, params = setup
    key = jax.random.PRNGKey(2)
    B, S, H, KV, hd = 2, 300, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, hd))
    for window, prefix in [(0, 0), (64, 0), (0, 37)]:
        o1 = L.blockwise_attention(q, k, v, causal=True, window=window,
                                   prefix_len=prefix, q_chunk=128, kv_chunk=64)
        o2 = L.plain_attention(q, k, v, causal=True, window=window,
                               prefix_len=prefix)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                                   rtol=2e-5)
