"""End-to-end behaviour tests: the paper's central claim — RaLMSpec preserves the
baseline's outputs exactly, across retriever types and feature variants.

Marked `slow` (run with `pytest -m slow`): the full variant sweep takes minutes.
The fast tier keeps the same claim guarded through
tests/test_output_preservation.py (fleet + batched-engine forms, which subsume
the single-request path at concurrency 1)."""
import dataclasses

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.knnlm import KNNLMSeq, KNNLMSpec
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.engine import ServeEngine
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    eng = ServeEngine(model, params, cache_window=256)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 2)]
    return cfg, model, params, docs, enc, dkb, skb, eng, prompts


RCFG = RaLMConfig(max_new_tokens=20, speculation_stride=3)


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
def test_output_preservation(stack, retr_name):
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = _retriever(retr_name, dkb, skb)
    seq = RaLMSeq(eng, retr, RCFG, enc)
    spec = RaLMSpec(eng, retr, RCFG, enc)
    for p in prompts:
        r1 = seq.serve(p)
        r2 = spec.serve(p)
        assert r1.tokens == r2.tokens, f"{retr_name}: outputs diverged"
        assert len(r1.tokens) == RCFG.max_new_tokens
        # spec issues the same queries, batched: fewer calls, >= as many queries
        assert r2.kb_calls <= r2.rounds + r2.mismatches + 1


@pytest.mark.parametrize("variant", ["p", "s", "a", "psa"])
def test_output_preservation_variants(stack, variant):
    """Prefetching / OS3 / async verification must not change outputs (Table 1)."""
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(
        RCFG,
        prefetch_top_k=20 if "p" in variant else 1,
        use_os3="s" in variant,
        async_verification="a" in variant,
    )
    seq = RaLMSeq(eng, retr, rcfg, enc)
    spec = RaLMSpec(eng, retr, rcfg, enc)
    r1 = seq.serve(prompts[0])
    r2 = spec.serve(prompts[0])
    assert r1.tokens == r2.tokens


def test_speculation_saves_kb_calls(stack):
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = ExactDenseRetriever(dkb)
    r2 = RaLMSpec(eng, retr, RCFG, enc).serve(prompts[0])
    r1 = RaLMSeq(eng, retr, RCFG, enc).serve(prompts[0])
    # baseline: one call per stride; spec: one batched call per round (+corrections)
    assert r2.kb_calls < r1.kb_calls


def test_knnlm_output_preservation(stack):
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs[:300]])
    ds = build_knn_datastore(stream, enc, context=16, limit=4000)
    kcfg = dataclasses.replace(RCFG, knnlm=True, knn_k=8, max_new_tokens=24)
    for retr in (ExactDenseRetriever(ds), IVFRetriever(ds, n_clusters=16, nprobe=2)):
        e2 = ServeEngine(model, params, cache_window=256)
        r1 = KNNLMSeq(e2, retr, kcfg, enc).serve(stream[:40].tolist())
        r2 = KNNLMSpec(e2, retr, kcfg, enc).serve(stream[:40].tolist())
        assert r1.tokens == r2.tokens
        assert r1.kb_calls == kcfg.max_new_tokens       # every-token retrieval
        assert r2.kb_calls < r1.kb_calls                # batched verification


def test_async_carry_verified_at_budget_boundary(stack):
    """Regression: the async overlap's carried speculative stride must be verified
    even when it exhausts the token budget — unverified tokens must never ship."""
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = ExactDenseRetriever(dkb)
    for mnt in (17, 20, 23):          # budgets that end mid/at-stride
        rcfg = dataclasses.replace(RCFG, async_verification=True,
                                   max_new_tokens=mnt)
        for p in prompts:
            r1 = RaLMSeq(eng, retr, rcfg, enc).serve(p)
            r2 = RaLMSpec(eng, retr, rcfg, enc).serve(p)
            assert r1.tokens == r2.tokens, f"budget {mnt}: async diverged"


def test_persistent_session_cache_preserves_outputs(stack):
    """Beyond-paper: the cross-request session cache must not change outputs
    (cache only steers speculation; verification still gates every doc)."""
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = ExactDenseRetriever(dkb)
    seq = RaLMSeq(eng, retr, RCFG, enc)
    spec = RaLMSpec(eng, retr, RCFG, enc, persistent_cache=True)
    for p in prompts + prompts:          # repeat: warm-cache requests too
        r1 = seq.serve(p)
        r2 = spec.serve(p)
        assert r1.tokens == r2.tokens


def test_rollback_restores_exact_state(stack):
    """Mis-speculation must leave no trace: serve twice, outputs identical."""
    cfg, model, params, docs, enc, dkb, skb, eng, prompts = stack
    retr = ExactDenseRetriever(dkb)
    spec = RaLMSpec(eng, retr, RCFG, enc)
    a = spec.serve(prompts[0])
    b = spec.serve(prompts[0])
    assert a.tokens == b.tokens
