"""The scan-rolled decode path (dry-run: decode_step_stacked) must numerically match
the per-layer serving path (decode_step) — same params, same state contents."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models.model import build_model, layer_plan, signatures


def _stack_state(model, flat_state):
    """Repack a per-layer state list into the stacked layout."""
    cfg = model.cfg
    n_pre, period, n_rep = layer_plan(cfg)
    prefix = tuple(flat_state[:n_pre])
    stages = []
    for j in range(period):
        if n_rep == 0:
            break
        reps = [flat_state[n_pre + r * period + j] for r in range(n_rep)]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                      if n_rep > 1 else reps[0])
    return {"prefix": prefix, "stages": tuple(stages)}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_stacked_decode_matches_flat(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    W, B = 24, 2
    toks = jax.random.randint(key, (B, 10), 0, cfg.vocab_size)
    extra = None
    if cfg.family == "audio":
        extra = {"frames": jax.random.normal(key, (B, cfg.encoder_frames,
                                                   cfg.d_model)) * 0.1}
    if cfg.family == "vlm":
        extra = {"patches": jax.random.normal(key, (B, cfg.vision_patches,
                                                    cfg.d_model)) * 0.1}
    # prefill through the serving path, then take ONE decode step both ways
    last, flat_state, pos = model.prefill(params, toks, extra=extra,
                                          window_cache=W)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    logits_flat, _ = model.decode_step(params, flat_state, tok, pos)

    stacked = _stack_state(model, flat_state)
    logits_stacked, new_stacked = model.decode_step_stacked(params, stacked,
                                                            tok, pos)
    # MoE: stacked (dry-run) uses capacity dispatch vs exact serving MoE — routed
    # outputs can differ by capacity drops; compare only for non-MoE archs, but the
    # function must still run and produce finite logits for all.
    assert bool(jnp.isfinite(logits_stacked).all()), arch
    if cfg.moe is None:
        err = float(jnp.max(jnp.abs(logits_stacked - logits_flat)))
        assert err < 2e-3, f"{arch}: stacked decode diverges by {err}"
    # state structure round-trips
    assert len(new_stacked["prefix"]) == layer_plan(cfg)[0]
