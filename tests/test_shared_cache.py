"""The fleet-scale shared speculation cache tier (ROADMAP item 1) and in-round
verification dedup:

  (a) unit: SharedRetrievalCache exact/approximate hit paths, LRU eviction,
      duplicate-put payload refresh, typed query keys (dense vs sparse), and
      SharedCacheView's pad/clamp + local fallback,
  (b) preservation: fleet / continuous / async serving with the shared tier
      enabled stays byte-identical to per-request RaLMSeq for EDR/ADR/SR —
      the tier is a speculation source only; verification confirms every doc,
  (c) dedup: byte-identical queries inside a round's merged verification call
      collapse to one KB row each (counters assert the reduction and the
      scatter-back preserves outputs),
  (d) the folded RaLMSpec(persistent_cache=True) path (now a private shared
      tier) still preserves outputs and actually carries hits across requests,
  (e) concurrency: a ThreadPoolExecutor hammering put/lookup leaves the tier
      structurally consistent (check_invariants) — the async fleet's worker
      thread publishes results while the main thread speculates.

CI runs this file on both the 1-device and 4-device platforms (the tier-1
matrix in .github/workflows/ci.yml); nothing here depends on device count.
"""
import dataclasses
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.cache import (DenseRetrievalCache, SharedCacheView,
                              SharedRetrievalCache, query_key)
from repro.core.ralmspec import RaLMSeq, RaLMSpec, dedup_queries
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


# ---------------------------------------------------------------------------------
# (a) the tier itself
# ---------------------------------------------------------------------------------
def _unit(v):
    v = np.asarray(v, np.float32)
    return v / np.linalg.norm(v)


def test_exact_hit_returns_stored_result_verbatim():
    s = SharedRetrievalCache(capacity=8)
    q = _unit([1.0, 2.0, 3.0])
    s.put(q, [4, 9], [0.7, 0.3])
    ids, sc = s.lookup(q)
    assert list(ids) == [4, 9]
    np.testing.assert_allclose(sc, [0.7, 0.3])
    assert s.stats()["hits_exact"] == 1
    # returned arrays are copies: mutating them can't corrupt the tier
    ids[0] = -5
    assert list(s.lookup(q)[0]) == [4, 9]


def test_approximate_hit_respects_threshold():
    s = SharedRetrievalCache(capacity=8, approx_threshold=0.95)
    s.put(_unit([1.0, 0.0]), [7], [0.5])
    near = _unit([1.0, 0.05])            # cosine ~0.9988
    far = _unit([1.0, 1.0])              # cosine ~0.707
    hit = s.lookup(near)
    assert hit is not None and list(hit[0]) == [7]
    assert s.lookup(far) is None
    st = s.stats()
    assert st["hits_approx"] == 1 and st["misses"] == 1
    # approx tier can be disabled outright
    s2 = SharedRetrievalCache(capacity=8, approx=False)
    s2.put(_unit([1.0, 0.0]), [7], [0.5])
    assert s2.lookup(near) is None


def test_sparse_queries_exact_only_and_typed_keys():
    s = SharedRetrievalCache(capacity=8)
    s.put([3, 1, 4], [2], [9.0])
    assert list(s.lookup([3, 1, 4])[0]) == [2]
    assert s.lookup([3, 1]) is None          # different terms: miss
    # a dense query whose bytes would collide can't hit the sparse entry
    assert query_key([3, 1, 4]) != query_key(np.asarray([3, 1, 4], np.float32))


def test_lru_eviction_and_duplicate_put_refresh():
    s = SharedRetrievalCache(capacity=2, approx=False)
    qa, qb, qc = _unit([1, 0, 0]), _unit([0, 1, 0]), _unit([0, 0, 1])
    s.put(qa, [1], [0.1])
    s.put(qb, [2], [0.2])
    s.put(qa, [10], [1.0])               # refresh: payload AND recency
    s.put(qc, [3], [0.3])                # evicts qb (LRU), not refreshed qa
    assert list(s.lookup(qa)[0]) == [10]
    assert s.lookup(qb) is None
    assert list(s.lookup(qc)[0]) == [3]
    assert s.stats()["evictions"] == 1
    s.check_invariants()


def test_view_pads_clamps_and_falls_back_to_local():
    shared = SharedRetrievalCache(capacity=8, approx=False)
    local = DenseRetrievalCache(3, capacity=8)
    view = SharedCacheView(local, shared)
    q_hit, q_miss = _unit([1, 0, 0]), _unit([0, 1, 0])
    shared.put(q_hit, [5, 6], [0.9, 0.8])
    local.insert([2], np.asarray(q_miss)[None])
    ids, sc = view.retrieve(q_hit, 4)            # shared hit, padded to k
    assert list(ids) == [5, 6, -1, -1]
    ids, _ = view.retrieve(q_hit, 1)             # clamped to k
    assert list(ids) == [5]
    ids, _ = view.retrieve(q_miss, 1)            # miss -> local cache
    assert list(ids) == [2]
    view.insert([9], np.zeros((1, 3), np.float32))   # writes go local-only
    assert 9 in local and len(shared) == 1
    assert view.size == local.size == 2


# ---------------------------------------------------------------------------------
# serving stack (same reduced fixture shape as tests/test_continuous.py)
# ---------------------------------------------------------------------------------
@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 4)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 2, cache_window=256)
    return docs, enc, dkb, skb, prompts, seng, beng


RCFG = RaLMConfig(max_new_tokens=16, speculation_stride=3)
BUDGETS = [16, 8, 12, 6]


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


def _seq_tokens(seng, retr, enc, rcfg, prompt, budget):
    one = dataclasses.replace(rcfg, max_new_tokens=budget)
    return RaLMSeq(seng, retr, one, enc).serve(prompt).tokens


# ---------------------------------------------------------------------------------
# (b) preservation with the shared tier on, every serving path x retriever
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
@pytest.mark.parametrize("path", ["fleet", "continuous", "async"])
def test_shared_cache_preserves_outputs(stack, path, retr_name):
    docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = _retriever(retr_name, dkb, skb)
    seq = [_seq_tokens(seng, retr, enc, RCFG, p, mn)
           for p, mn in zip(prompts, BUDGETS)]
    shared = SharedRetrievalCache(capacity=256)
    if path == "continuous":
        cr = ContinuousFleetServer(beng, retr, RCFG, enc,
                                   shared_cache=shared).serve(
            as_requests(prompts, max_new=BUDGETS))
        got = [r.tokens for r in cr.results]
    else:
        # async: force overlapped strides so the worker thread publishes to
        # the tier while the main thread's overlap stride reads from it
        rcfg = (dataclasses.replace(RCFG, async_gate_ratio=0.0,
                                    async_min_overlap=2)
                if path == "async" else RCFG)
        with FleetServer(beng, retr, rcfg, enc,
                         async_rounds=(path == "async"),
                         shared_cache=shared) as fleet:
            got = []
            for i in range(0, len(prompts), beng.n_slots):
                fr = fleet.serve(prompts[i:i + beng.n_slots],
                                 max_new=BUDGETS[i:i + beng.n_slots])
                got.extend(r.tokens for r in fr.results)
    assert got == seq, f"{path}/{retr_name}: shared cache changed outputs"
    assert shared.stats()["puts"] > 0, "verification never published"


def test_shared_tier_carries_hits_across_requests(stack):
    """Serving the same prompt twice through fresh slots must hit the tier
    the second time (that's the amortization the tier exists for)."""
    docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    shared = SharedRetrievalCache(capacity=256)
    fleet = FleetServer(beng, retr, RCFG, enc, shared_cache=shared)
    fleet.serve([prompts[0], prompts[1]])
    before = shared.stats()["hits_exact"] + shared.stats()["hits_approx"]
    fleet.serve([prompts[0], prompts[1]])      # same prompts, fresh states
    after = shared.stats()["hits_exact"] + shared.stats()["hits_approx"]
    assert after > before, "identical re-serve never hit the shared tier"


# ---------------------------------------------------------------------------------
# (c) in-round verification dedup
# ---------------------------------------------------------------------------------
def test_dedup_queries_scatter_identity():
    qs = [[1, 2], [3], [1, 2], [3], [1, 2]]
    uniq, inv = dedup_queries(qs)
    assert len(uniq) == 2
    assert [uniq[i] for i in inv] == qs


def test_dedup_reduces_merged_rows_and_preserves_outputs(stack):
    """Identical prompts in sibling slots issue byte-identical verification
    queries every round — dedup must collapse them to one KB row each, and
    the scatter-back must leave tokens untouched."""
    docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    twin = [prompts[0], prompts[0]]            # both slots run the same prompt
    on = FleetServer(beng, retr, RCFG, enc).serve(twin)
    assert on.merged_rows_saved > 0, "identical queries were not collapsed"
    rcfg_off = dataclasses.replace(RCFG, dedup_verification=False)
    off = FleetServer(beng, retr, rcfg_off, enc).serve(twin)
    assert off.merged_rows_saved == 0
    assert on.merged_rows < off.merged_rows
    assert on.kb_queries < off.kb_queries      # fewer rows hit the KB
    assert [r.tokens for r in on.results] == [r.tokens for r in off.results]
    # the seed call dedups too: 2 identical prompts -> 1 seed row
    assert on.merged_rows_saved >= off.merged_rows - on.merged_rows


def test_continuous_reports_dedup_ledger(stack):
    docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    cr = ContinuousFleetServer(beng, retr, RCFG, enc).serve(
        as_requests([prompts[0], prompts[0], prompts[0]], max_new=[8, 8, 8]))
    assert cr.merged_rows > 0
    assert cr.merged_rows_saved > 0, \
        "identical co-resident prompts should dedup in the merged call"


# ---------------------------------------------------------------------------------
# (d) the folded persistent_cache path
# ---------------------------------------------------------------------------------
def test_persistent_cache_is_the_shared_tier_and_preserves(stack):
    docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    seq = [_seq_tokens(seng, retr, enc, RCFG, p, 16) for p in prompts[:2]]
    spec = RaLMSpec(seng, retr, RCFG, enc, persistent_cache=True)
    assert isinstance(spec.shared_cache, SharedRetrievalCache)
    got = [spec.serve(p).tokens for p in prompts[:2]]
    assert got == seq
    assert spec.shared_cache.stats()["puts"] > 0


# ---------------------------------------------------------------------------------
# (e) concurrent access
# ---------------------------------------------------------------------------------
def test_concurrent_put_lookup_stress():
    """Many threads hammering a tiny tier (constant eviction) must leave it
    structurally consistent and never return a torn result."""
    s = SharedRetrievalCache(capacity=16, approx_threshold=0.999)
    rng = np.random.default_rng(0)
    queries = [_unit(rng.standard_normal(8)) for _ in range(64)]
    payload = {query_key(q): i for i, q in enumerate(queries)}

    def worker(wid):
        g = np.random.default_rng(wid)
        for _ in range(300):
            q = queries[int(g.integers(len(queries)))]
            if g.random() < 0.5:
                i = payload[query_key(q)]
                s.put(q, [i, i + 1], [1.0, 0.5])
            else:
                hit = s.lookup(q)
                if hit is not None:
                    ids, sc = hit
                    # results are never torn: stored rows are internally
                    # consistent (id pair matches what some put wrote)
                    assert ids[1] == ids[0] + 1 and len(ids) == len(sc) == 2
        return True

    with ThreadPoolExecutor(max_workers=8) as ex:
        assert all(ex.map(worker, range(8)))
    s.check_invariants()
    st = s.stats()
    assert st["size"] <= 16 and st["evictions"] > 0
    assert st["lookups"] + st["puts"] == 8 * 300
