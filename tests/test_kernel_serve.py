"""The Pallas dense-top-k EDR backend is reachable from the serving stack
(`--retriever-backend kernel` in repro.launch.serve) and serves the SAME
tokens as the numpy EDR — kernel-level parity is covered by tests/test_kernels;
this is the end-to-end guard: a short speculative serve routed through
`kernels.dense_topk` (interpret mode on CPU) must be byte-identical."""
import jax
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB
from repro.retrieval.retrievers import ExactDenseRetriever
from repro.serving.engine import ServeEngine
from repro.training.data import make_queries, synthetic_corpus


def test_kernel_backend_serve_parity():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    kb = DenseKB.build(docs, enc)
    rcfg = RaLMConfig(max_new_tokens=12, speculation_stride=3)
    prompt = [(q * 10)[:32] for q in make_queries(docs, 1)][0]
    eng = ServeEngine(model, params, cache_window=256)
    r_np = RaLMSpec(eng, ExactDenseRetriever(kb), rcfg, enc).serve(prompt)
    r_kr = RaLMSpec(eng, ExactDenseRetriever(kb, backend="kernel"),
                    rcfg, enc).serve(prompt)
    assert r_kr.tokens == r_np.tokens, \
        "kernel-backend EDR changed served tokens"
    assert len(r_kr.tokens) == rcfg.max_new_tokens
