"""Distribution layer: sharding-rule properties, sharded retrieval, dry-run smoke."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import _sanitize, param_specs, state_specs
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _specs_for(arch, mesh, **kw):
    model = build_model(get_config(arch))
    ps = jax.eval_shape(lambda k: model.init(k, jnp.bfloat16), jax.random.PRNGKey(0))
    return ps, param_specs(ps, mesh, **kw)


def _sharded_fraction(params, specs, sizes):
    tot = tot_sh = 0
    leaves_p = jax.tree.leaves(params)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves_p, leaves_s):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        div = 1
        for e in spec:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                div *= sizes[a]
        tot += nbytes
        tot_sh += nbytes // div
    return tot, tot_sh


def test_sanitize_drops_nondivisible():
    sizes = {"data": 16, "model": 16}
    assert _sanitize(P("model"), (8,), sizes) == P(None)        # 8 % 16 != 0
    assert _sanitize(P("model"), (32,), sizes) == P("model")
    assert _sanitize(P(("data", "model")), (256,), sizes) == P(("data", "model"))
    assert _sanitize(P("pod"), (32,), sizes) == P(None)          # axis absent


@pytest.mark.parametrize("arch,max_ratio", [
    ("kimi-k2-1t-a32b", 1.05), ("qwen1.5-110b", 1.05),
    ("command-r-plus-104b", 1.05), ("jamba-v0.1-52b", 1.10),
])
def test_param_sharding_near_ideal(arch, max_ratio):
    """Per-device parameter bytes within a few % of total/256 on the 16x16 mesh."""
    import jax.sharding
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    params, specs = _specs_for(arch, mesh)
    tot, tot_sh = _sharded_fraction(params, specs,
                                    {"data": 16, "model": 16})
    assert tot_sh <= (tot / 256) * max_ratio, \
        f"{arch}: {tot_sh/1e9:.2f}GB/device vs ideal {tot/256/1e9:.2f}GB"


def test_tp_false_replicates_weights():
    import jax.sharding
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    params, specs = _specs_for("xlstm-350m", mesh, fsdp=False, tp=False)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in s), s


def test_state_specs_kv_modes():
    import jax.sharding
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    model = build_model(get_config("llama3.2-1b"))
    st = jax.eval_shape(lambda: model.init_decode_state_stacked(128, 32768,
                                                                jnp.bfloat16))
    for mode, want_axis in [("replicated", None), ("window", "model")]:
        specs = state_specs(st, mesh, 128, kv_shard=mode)
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        k_specs = [s for s in flat if len(s) == 5]  # stacked (rep,B,W,KV,hd)
        assert k_specs, "no stacked KV specs found"
        for s in k_specs:
            assert s[2] == want_axis, (mode, s)


def test_sharded_retrieval_matches_ref():
    from repro.kernels.ref import dense_topk_ref
    from repro.retrieval.sharded import mesh_context, sharded_dense_topk
    mesh = make_local_mesh()
    kq, kk = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (4, 32))
    kb = jax.random.normal(kk, (1000, 32))
    with mesh_context(mesh):
        s1, g1 = sharded_dense_topk(q, kb, 8, mesh, axis="model")
    s2, g2 = dense_topk_ref(q, kb, 8)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


@pytest.mark.slow
def test_dryrun_pair_subprocess():
    """One cheap (arch x shape) pair lowers+compiles on the 512-device platform."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.dryrun import dryrun_pair;"
        "r = dryrun_pair('xlstm-350m','long_500k',verbose=False);"
        "print('DRYRUN_OK' if r['ok'] else r['error'])"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env)
    assert "DRYRUN_OK" in out.stdout, out.stdout + out.stderr
