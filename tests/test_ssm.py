"""SSM mixers: chunkwise/parallel forms must equal the stepwise recurrences
(the stepwise form is both the decode path and the oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import ssm as SSM

CFG_M = reduced(get_config("jamba-v0.1-52b"))          # mamba dims
CFG_X = reduced(get_config("xlstm-350m"))              # mlstm/slstm dims


def _roll(step_fn, p, cfg, x, state):
    outs = []
    for t in range(x.shape[1]):
        o, state = step_fn(p, cfg, x[:, t:t + 1], state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


@pytest.mark.parametrize("S", [1, 7, 32, 65])
def test_mamba_chunked_equals_stepwise(S):
    key = jax.random.PRNGKey(S)
    p = SSM.init_mamba(key, CFG_M, jnp.float32)
    x = jax.random.normal(key, (2, S, CFG_M.d_model)) * 0.3
    y_par = SSM.apply_mamba(p, CFG_M, x)
    y_seq, _ = _roll(SSM.apply_mamba_step, p, CFG_M,
                     x, SSM.init_mamba_state(CFG_M, 2, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("S", [1, 9, 32, 70])
def test_mlstm_chunkwise_equals_stepwise(S):
    key = jax.random.PRNGKey(S + 100)
    p = SSM.init_mlstm(key, CFG_X, jnp.float32)
    x = jax.random.normal(key, (2, S, CFG_X.d_model)) * 0.3
    y_par = SSM.apply_mlstm(p, CFG_X, x)
    y_seq, _ = _roll(SSM.apply_mlstm_step, p, CFG_X,
                     x, SSM.init_mlstm_state(CFG_X, 2, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("S", [1, 8, 33])
def test_slstm_scan_equals_stepwise(S):
    key = jax.random.PRNGKey(S + 200)
    p = SSM.init_slstm(key, CFG_X, jnp.float32)
    x = jax.random.normal(key, (2, S, CFG_X.d_model)) * 0.3
    y_par = SSM.apply_slstm(p, CFG_X, x)
    y_seq, _ = _roll(SSM.apply_slstm_step, p, CFG_X,
                     x, SSM.init_slstm_state(CFG_X, 2, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=5e-4, rtol=5e-3)


def test_mamba_state_carries_across_chunk_boundaries():
    """Chunk size must not change results (state threading across chunks)."""
    import dataclasses
    key = jax.random.PRNGKey(5)
    p = SSM.init_mamba(key, CFG_M, jnp.float32)
    x = jax.random.normal(key, (1, 64, CFG_M.d_model)) * 0.3
    cfg_small = dataclasses.replace(
        CFG_M, ssm=dataclasses.replace(CFG_M.ssm, chunk=8))
    cfg_big = dataclasses.replace(
        CFG_M, ssm=dataclasses.replace(CFG_M.ssm, chunk=64))
    y1 = SSM.apply_mamba(p, cfg_small, x)
    y2 = SSM.apply_mamba(p, cfg_big, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-3)


def test_mlstm_gates_bounded():
    """Capped exponential gating never overflows (long sequence, large inputs)."""
    key = jax.random.PRNGKey(6)
    p = SSM.init_mlstm(key, CFG_X, jnp.float32)
    x = jax.random.normal(key, (1, 256, CFG_X.d_model)) * 5.0
    y = SSM.apply_mlstm(p, CFG_X, x)
    assert bool(jnp.isfinite(y).all())
