"""Output preservation under async (pipelined) fleet rounds — the paper's +A
extended fleet-wide must not change a single token:

  (a) async fleet == per-request RaLMSeq for EDR/ADR/SR, with the overlap
      actually exercised (carried steps > 0 — the gate is forced open via
      ``async_gate_ratio=0``),
  (b) forced rollbacks (capacity-1 cache) that INVALIDATE overlapped strides
      still preserve outputs, and the invalidations are observable
      (``ServeResult.carry_invalidations``),
  (c) continuous-batching churn composes with pipelined rounds: admissions
      whose requests arrived while a verification call was in flight ride
      that call for pre-seeding, slots with pending carries cannot retire,
      and every request's tokens still match per-request RaLMSeq,
  (d) the adaptive gate: a huge ratio disables the overlap (ADR-style
      degradation to sync rounds) without changing outputs,
  (e) the multi-step carry generalization keeps the single-request async
      path byte-identical (budget ending mid-carry).

Engines are module-scoped (serve()/start() reset them) so jit caches are
shared across tests — the fast tier pays each prefill shape once.
"""
import dataclasses

import jax
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSeq
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 3)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 3, cache_window=256)
    beng2 = BatchedServeEngine(model, params, 2, cache_window=256)
    return model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2


# gate ratio 0 opens the overlap gate every round (b_est > 0 after the seed
# call) and min_overlap forces the overlapped sub-steps past the verification
# window, so the carry machinery is exercised deterministically on this tiny
# stack, whose retrieval is far too cheap to hide anything behind
RCFG = RaLMConfig(max_new_tokens=20, speculation_stride=3,
                  async_gate_ratio=0.0, async_min_overlap=16)
BUDGETS = [20, 8, 14]


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


def _seq_tokens(seng, retr, enc, rcfg, prompt, budget=None):
    one = rcfg if budget is None else dataclasses.replace(
        rcfg, max_new_tokens=budget)
    return RaLMSeq(seng, retr, one, enc).serve(prompt).tokens


# ---------------------------------------------------------------------------------
# (a) async fleet == per-request RaLMSeq, every retriever, overlap exercised
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
def test_async_fleet_output_preservation(stack, retr_name):
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = _retriever(retr_name, dkb, skb)
    seq = [_seq_tokens(seng, retr, enc, RCFG, p) for p in prompts]
    fr = FleetServer(beng, retr, RCFG, enc, async_rounds=True).serve(prompts)
    for i, r in enumerate(fr.results):
        assert r.tokens == seq[i], f"{retr_name}: slot {i} diverged"
    # the pipeline really ran: overlapped strides happened (kept or revoked)
    assert sum(r.carry_steps + r.carry_invalidations for r in fr.results) > 0
    # and the merge invariant survives it: ONE KB call per round (+ seed)
    assert fr.kb_calls == fr.rounds + 1


def test_async_fleet_matches_sync_fleet(stack):
    """Pipelining is a latency optimization, not a decoding change: sync and
    async fleets serve identical tokens."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    sync = FleetServer(beng, retr, RCFG, enc, async_rounds=False).serve(prompts)
    asyn = FleetServer(beng, retr, RCFG, enc, async_rounds=True).serve(prompts)
    assert [r.tokens for r in asyn.results] == [r.tokens for r in sync.results]


# ---------------------------------------------------------------------------------
# (b) rollbacks that invalidate overlapped strides
# ---------------------------------------------------------------------------------
def test_async_fleet_rollback_invalidates_overlap(stack):
    """Capacity-1 cache: heavy mis-speculation while every round overlaps the
    next stride — mismatched slots must rewind their overlapped work (the
    invalidation path) and outputs must still equal the baseline."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, cache_capacity=1)
    seq = [_seq_tokens(seng, retr, enc, rcfg, p) for p in prompts]
    fr = FleetServer(beng, retr, rcfg, enc, async_rounds=True).serve(prompts)
    assert sum(r.mismatches for r in fr.results) > 0, \
        "capacity-1 cache should force mis-speculation"
    assert sum(r.carry_invalidations for r in fr.results) > 0, \
        "a rollback should have invalidated an overlapped stride"
    for i, r in enumerate(fr.results):
        assert r.tokens == seq[i], f"slot {i} kept invalidated overlap work"


# ---------------------------------------------------------------------------------
# (c) continuous batching churn composes with pipelined rounds
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
def test_async_continuous_preservation_under_churn(stack, retr_name):
    """3 requests through 2 slots with heterogeneous budgets: queueing, slot
    reuse, and retirement all happen between pipelined rounds; arrivals with
    small offsets land while a verification call is in flight and ride it
    for pre-seeding. Every request must match per-request RaLMSeq."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = _retriever(retr_name, dkb, skb)
    seq = [_seq_tokens(seng, retr, enc, RCFG, p, mn)
           for p, mn in zip(prompts, BUDGETS)]
    server = ContinuousFleetServer(beng2, retr, RCFG, enc, async_rounds=True)
    cr = server.serve(as_requests(prompts, arrivals=[0, 0, 1e-4],
                                  max_new=BUDGETS))
    for i, r in enumerate(cr.results):
        assert r.tokens == seq[i], f"{retr_name}: request {i} diverged"
        assert len(r.tokens) == BUDGETS[i]
    assert cr.kb_calls == cr.rounds + cr.seed_calls
    assert cr.seed_calls == 1, "mid-flight arrivals should ride the call"


def test_async_continuous_rollbacks_under_churn(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, cache_capacity=1)
    seq = [_seq_tokens(seng, retr, enc, rcfg, p, mn)
           for p, mn in zip(prompts, BUDGETS)]
    cr = ContinuousFleetServer(beng2, retr, rcfg, enc,
                               async_rounds=True).serve(
        as_requests(prompts, max_new=BUDGETS))
    assert sum(r.mismatches for r in cr.results) > 0
    for i, r in enumerate(cr.results):
        assert r.tokens == seq[i], f"request {i} perturbed by churn+rollback"


# ---------------------------------------------------------------------------------
# (d) adaptive gate: overlap disabled -> sync behavior, same outputs
# ---------------------------------------------------------------------------------
def test_async_fleet_gate_closes_for_cheap_retrievers(stack):
    """A gate ratio no measured b can clear models the ADR regime (paper
    Table 4: +A hurts cheap retrievers): the async fleet must take ZERO
    overlapped steps and still serve baseline-identical tokens."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, async_gate_ratio=1e12)
    seq = [_seq_tokens(seng, retr, enc, rcfg, p) for p in prompts]
    fr = FleetServer(beng, retr, rcfg, enc, async_rounds=True).serve(prompts)
    assert sum(r.carry_steps + r.carry_invalidations
               for r in fr.results) == 0, "gate should have closed"
    for i, r in enumerate(fr.results):
        assert r.tokens == seq[i]


# ---------------------------------------------------------------------------------
# (e) measured wall-clock overlap ledger (monotonic clock)
# ---------------------------------------------------------------------------------
def test_overlap_ledger_consistency(stack):
    """Sync fleets measure verification wall but no overlap (exact zeros);
    async fleets with the gate forced open record overlapped-stride wall and
    a span intersection bounded by both sides: 0 <= measured <= min(verify,
    overlap). The strictly-positive overlap claim is the perf-marked test
    below — this one must hold on any scheduler."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    sync = FleetServer(beng, retr, RCFG, enc, async_rounds=False).serve(prompts)
    assert sync.verify_wall_s > 0
    assert sync.overlap_wall_s == 0.0 and sync.measured_overlap_s == 0.0
    asyn = FleetServer(beng, retr, RCFG, enc, async_rounds=True).serve(prompts)
    assert asyn.verify_wall_s > 0
    assert asyn.overlap_wall_s > 0, "gate ratio 0 must overlap every round"
    assert 0.0 <= asyn.measured_overlap_s
    assert asyn.measured_overlap_s \
        <= min(asyn.verify_wall_s, asyn.overlap_wall_s) + 1e-9


@pytest.mark.perf
def test_overlap_ledger_measures_real_concurrency(stack):
    """Wall-clock-sensitive (deselected from the CI fast tier): with the gate
    forced open and long overlap strides, the worker's KB call and the main
    thread's stride must DEMONSTRABLY run concurrently — a positive monotonic
    span intersection. numpy BLAS and jit'd XLA release the GIL, so this
    holds even on one core; the loose threshold (> 0, not a fraction) keeps
    it scheduler-tolerant."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    fr = FleetServer(beng, retr, RCFG, enc, async_rounds=True).serve(prompts)
    assert fr.overlap_wall_s > 0
    assert fr.measured_overlap_s > 0, \
        "no measured concurrency between KB call and overlapped stride"


# ---------------------------------------------------------------------------------
# (f) single-request path on the generalized multi-step carry
# ---------------------------------------------------------------------------------
def test_single_request_carry_budget_boundary(stack):
    """Budget 17 ends mid-stride with a pending carry — the generalized
    (list) carry must keep the single async path byte-identical."""
    from repro.core.ralmspec import RaLMSpec
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, async_verification=True,
                               max_new_tokens=17, async_gate_ratio=0.6)
    r1 = RaLMSeq(seng, retr, rcfg, enc).serve(prompts[0])
    r2 = RaLMSpec(seng, retr, rcfg, enc).serve(prompts[0])
    assert r1.tokens == r2.tokens
