"""Property-based tests (hypothesis) for the paper's rank-preservation invariant
(§3): if the KB top-1 document for a query is in the local cache, cache retrieval
returns exactly that document — for both dense and BM25 scoring."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.cache import DenseRetrievalCache, SparseRetrievalCache
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import BM25Retriever, ExactDenseRetriever
from repro.training.data import synthetic_corpus


@st.composite
def dense_case(draw):
    n = draw(st.integers(8, 64))
    d = draw(st.sampled_from([4, 8, 16]))
    seed = draw(st.integers(0, 10_000))
    g = np.random.default_rng(seed)
    emb = g.standard_normal((n, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    q = g.standard_normal(d).astype(np.float32)
    cached = draw(st.sets(st.integers(0, n - 1), min_size=1, max_size=n))
    return emb, q, sorted(cached)


@given(dense_case())
@settings(max_examples=80, deadline=None)
def test_dense_rank_preservation(case):
    emb, q, cached = case
    top_kb = int(np.argmax(emb @ q))
    cache = DenseRetrievalCache(emb.shape[1], capacity=len(cached) + 4)
    cache.insert(np.asarray(cached), emb[cached])
    ids, _ = cache.retrieve(q, 1)
    if top_kb in cached:
        assert int(ids[0]) == top_kb
    else:
        # cache returns its best — which can never out-score the KB top-1
        best_cached = cached[int(np.argmax(emb[cached] @ q))]
        assert int(ids[0]) == best_cached


@given(st.integers(0, 5000), st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_sparse_rank_preservation(seed, nq):
    docs = synthetic_corpus(60, 256, n_topics=4, seed=seed % 97)
    kb = SparseKB.build(docs)
    g = np.random.default_rng(seed)
    query = list(g.integers(2, 256, nq))
    kb_scores = kb.score(query)
    top_kb = int(np.argmax(kb_scores))
    cached = sorted(set(g.integers(0, 60, 20).tolist()) | {top_kb})
    cache = SparseRetrievalCache(kb, capacity=64)
    cache.insert(np.asarray(cached))
    ids, sc = cache.retrieve(query, 1)
    # identical metric + global stats => cached top-1 == KB top-1 when present
    assert int(ids[0]) == top_kb or np.isclose(sc[0], kb_scores[top_kb])


@given(st.integers(2, 30), st.integers(1, 120))
@settings(max_examples=40, deadline=None)
def test_cache_lru_eviction_and_capacity(cap, n_ins):
    d = 8
    g = np.random.default_rng(cap * 1000 + n_ins)
    cache = DenseRetrievalCache(d, capacity=cap)
    keys = g.standard_normal((n_ins, d)).astype(np.float32)
    for i in range(n_ins):
        cache.insert([i], keys[i:i + 1])
    assert cache.size == min(cap, n_ins)
    # most recent insertions survive
    for i in range(max(0, n_ins - cap), n_ins):
        assert i in cache


def test_cache_scores_equal_kb_scores_dense():
    docs = synthetic_corpus(200, 512)
    from repro.retrieval.encoder import ContextEncoder
    enc = ContextEncoder(512, d=16)
    kb = DenseKB.build(docs, enc)
    r = ExactDenseRetriever(kb)
    q = enc.encode(docs[5][:10])
    ids, scores = r.retrieve(q[None], 8)
    cache = DenseRetrievalCache(16, 64)
    cache.insert(ids[0], r.keys_of(ids[0]))
    cids, cscores = cache.retrieve(q, 8)
    np.testing.assert_allclose(np.sort(cscores)[::-1], np.sort(scores[0])[::-1],
                               atol=1e-5)
    assert int(cids[0]) == int(ids[0, 0])


def test_bm25_cache_scores_equal_kb_scores():
    docs = synthetic_corpus(120, 256)
    kb = SparseKB.build(docs)
    r = BM25Retriever(kb)
    query = docs[7][:6]
    ids, scores = r.retrieve([query], 5)
    cache = SparseRetrievalCache(kb, 32)
    cache.insert(ids[0])
    cids, cscores = cache.retrieve(query, 5)
    np.testing.assert_allclose(cscores, scores[0], atol=1e-5)
    assert list(cids) == list(ids[0])
