"""Property-based tests (hypothesis) for the paper's rank-preservation invariant
(§3): if the KB top-1 document for a query is in the local cache, cache retrieval
returns exactly that document — for both dense and BM25 scoring; plus the
canonical tie-order contract (score desc, id asc — parity with FlatBackend on
tie-heavy KBs), LRU eviction edge cases (capacity=1, k > size, duplicate-heavy
insert streams), and payload refresh on duplicate insert."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.cache import DenseRetrievalCache, SparseRetrievalCache
from repro.retrieval.backends import FlatBackend
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import BM25Retriever, ExactDenseRetriever
from repro.training.data import synthetic_corpus


@st.composite
def dense_case(draw):
    n = draw(st.integers(8, 64))
    d = draw(st.sampled_from([4, 8, 16]))
    seed = draw(st.integers(0, 10_000))
    g = np.random.default_rng(seed)
    emb = g.standard_normal((n, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9
    q = g.standard_normal(d).astype(np.float32)
    cached = draw(st.sets(st.integers(0, n - 1), min_size=1, max_size=n))
    return emb, q, sorted(cached)


@given(dense_case())
@settings(max_examples=80, deadline=None)
def test_dense_rank_preservation(case):
    emb, q, cached = case
    top_kb = int(np.argmax(emb @ q))
    cache = DenseRetrievalCache(emb.shape[1], capacity=len(cached) + 4)
    cache.insert(np.asarray(cached), emb[cached])
    ids, _ = cache.retrieve(q, 1)
    if top_kb in cached:
        assert int(ids[0]) == top_kb
    else:
        # cache returns its best — which can never out-score the KB top-1
        best_cached = cached[int(np.argmax(emb[cached] @ q))]
        assert int(ids[0]) == best_cached


@given(st.integers(0, 5000), st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_sparse_rank_preservation(seed, nq):
    docs = synthetic_corpus(60, 256, n_topics=4, seed=seed % 97)
    kb = SparseKB.build(docs)
    g = np.random.default_rng(seed)
    query = list(g.integers(2, 256, nq))
    kb_scores = kb.score(query)
    top_kb = int(np.argmax(kb_scores))
    cached = sorted(set(g.integers(0, 60, 20).tolist()) | {top_kb})
    cache = SparseRetrievalCache(kb, capacity=64)
    cache.insert(np.asarray(cached))
    ids, sc = cache.retrieve(query, 1)
    # identical metric + global stats => cached top-1 == KB top-1 when present
    assert int(ids[0]) == top_kb or np.isclose(sc[0], kb_scores[top_kb])


@given(st.integers(2, 30), st.integers(1, 120))
@settings(max_examples=40, deadline=None)
def test_cache_lru_eviction_and_capacity(cap, n_ins):
    d = 8
    g = np.random.default_rng(cap * 1000 + n_ins)
    cache = DenseRetrievalCache(d, capacity=cap)
    keys = g.standard_normal((n_ins, d)).astype(np.float32)
    for i in range(n_ins):
        cache.insert([i], keys[i:i + 1])
    assert cache.size == min(cap, n_ins)
    # most recent insertions survive
    for i in range(max(0, n_ins - cap), n_ins):
        assert i in cache


def test_cache_scores_equal_kb_scores_dense():
    docs = synthetic_corpus(200, 512)
    from repro.retrieval.encoder import ContextEncoder
    enc = ContextEncoder(512, d=16)
    kb = DenseKB.build(docs, enc)
    r = ExactDenseRetriever(kb)
    q = enc.encode(docs[5][:10])
    ids, scores = r.retrieve(q[None], 8)
    cache = DenseRetrievalCache(16, 64)
    cache.insert(ids[0], r.keys_of(ids[0]))
    cids, cscores = cache.retrieve(q, 8)
    np.testing.assert_allclose(np.sort(cscores)[::-1], np.sort(scores[0])[::-1],
                               atol=1e-5)
    assert int(cids[0]) == int(ids[0, 0])


def test_bm25_cache_scores_equal_kb_scores():
    docs = synthetic_corpus(120, 256)
    kb = SparseKB.build(docs)
    r = BM25Retriever(kb)
    query = docs[7][:6]
    ids, scores = r.retrieve([query], 5)
    cache = SparseRetrievalCache(kb, 32)
    cache.insert(ids[0])
    cids, cscores = cache.retrieve(query, 5)
    np.testing.assert_allclose(cscores, scores[0], atol=1e-5)
    assert list(cids) == list(ids[0])


# ---------------------------------------------------------------------------------
# canonical tie order: cache retrieval == FlatBackend on tie-heavy KBs
# ---------------------------------------------------------------------------------
@st.composite
def tie_heavy_dense(draw):
    """Grid-quantized embeddings tiled from a tiny base: float32 dot products
    are exact (integers/2) and most scores collide, so every tie-break path is
    exercised. Insertion order is a permutation — the cache's LRU slot layout
    must never leak into the returned order."""
    d = draw(st.sampled_from([4, 8]))
    base = draw(st.integers(2, 4))
    reps = draw(st.integers(2, 6))
    seed = draw(st.integers(0, 10_000))
    g = np.random.default_rng(seed)
    emb = np.tile(g.integers(-2, 3, size=(base, d)).astype(np.float32) / 2,
                  (reps, 1))
    q = g.integers(-2, 3, size=d).astype(np.float32) / 2
    order = g.permutation(emb.shape[0])
    k = draw(st.integers(1, emb.shape[0]))
    return emb, q, order, k


@given(tie_heavy_dense())
@settings(max_examples=60, deadline=None)
def test_dense_cache_tie_order_matches_flat_backend(case):
    emb, q, order, k = case
    cache = DenseRetrievalCache(emb.shape[1], capacity=emb.shape[0])
    for i in order:                      # arbitrary LRU slot layout
        cache.insert([int(i)], emb[i:i + 1])
    cids, cscores = cache.retrieve(q, k)
    ids, scores = FlatBackend(emb).search(q[None], k)
    assert list(cids) == list(ids[0]), \
        "cache tie order diverged from the canonical backend order"
    np.testing.assert_array_equal(cscores, scores[0])


@given(st.integers(0, 3000), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_sparse_cache_tie_order_matches_bm25_retriever(seed, k):
    g = np.random.default_rng(seed)
    docs = synthetic_corpus(24, 128, n_topics=2, seed=seed % 53)
    docs = [docs[i % 8] for i in range(24)]      # duplicates -> exact ties
    kb = SparseKB.build(docs)
    r = BM25Retriever(kb)
    query = list(g.integers(2, 128, 4))
    ids, scores = r.retrieve([query], k)
    cache = SparseRetrievalCache(kb, capacity=32)
    cache.insert(g.permutation(24))              # arbitrary slot layout
    cids, cscores = cache.retrieve(query, k)
    assert list(cids) == list(ids[0]), \
        "sparse cache tie order diverged from BM25Retriever"
    np.testing.assert_allclose(cscores, scores[0], atol=1e-5)


# ---------------------------------------------------------------------------------
# LRU eviction edge cases + duplicate-insert payload refresh
# ---------------------------------------------------------------------------------
@given(st.integers(0, 2000), st.integers(1, 40))
@settings(max_examples=40, deadline=None)
def test_capacity_one_cache_holds_exactly_last_insert(seed, n_ins):
    g = np.random.default_rng(seed)
    cache = DenseRetrievalCache(4, capacity=1)
    ids = g.integers(0, 10, n_ins)
    keys = g.standard_normal((n_ins, 4)).astype(np.float32)
    for i in range(n_ins):
        cache.insert([int(ids[i])], keys[i:i + 1])
    assert cache.size == 1
    last = int(ids[-1])
    assert last in cache
    got, sc = cache.retrieve(g.standard_normal(4).astype(np.float32), 3)
    assert int(got[0]) == last
    # k > size: padded with -1 ids and -inf scores
    assert list(got[1:]) == [-1, -1]
    assert np.all(np.isneginf(sc[1:]))


@given(st.integers(2, 12), st.lists(st.integers(0, 4), min_size=1,
                                    max_size=60))
@settings(max_examples=50, deadline=None)
def test_duplicate_heavy_insert_stream_lru(cap, stream):
    """Only 5 distinct ids through any capacity: size never exceeds the
    distinct count, nothing is evicted while it fits, and the LRU victim under
    overflow is the least-recently *touched* id (insert touches)."""
    cache = DenseRetrievalCache(2, capacity=cap)
    g = np.random.default_rng(cap)
    last_touch = {}
    for t, did in enumerate(stream):
        cache.insert([did], g.standard_normal((1, 2)).astype(np.float32))
        last_touch[did] = t
    distinct = len(last_touch)
    assert cache.size == min(distinct, cap)
    survivors = sorted(last_touch, key=last_touch.get)[-cache.size:]
    for did in survivors:
        assert did in cache
    for did in set(last_touch) - set(survivors):
        assert did not in cache


@given(st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_duplicate_insert_refreshes_key_and_value(seed):
    """Re-inserting a resident id must overwrite its stored key AND value —
    a stale key mis-scores speculation; a stale value poisons values_of
    (the KNN-LM payload path)."""
    g = np.random.default_rng(seed)
    cache = DenseRetrievalCache(4, capacity=8)
    k_old = g.standard_normal((1, 4)).astype(np.float32)
    k_new = g.standard_normal((1, 4)).astype(np.float32)
    cache.insert([3], k_old, [111])
    cache.insert([5], g.standard_normal((1, 4)).astype(np.float32), [55])
    cache.insert([3], k_new, [222])
    assert cache.size == 2
    assert list(cache.values_of([3, 5])) == [222, 55]
    q = g.standard_normal(4).astype(np.float32)
    ids, sc = cache.retrieve(q, 2)
    expect = float(k_new[0] @ q)
    got = float(sc[list(ids).index(3)])
    assert np.isclose(got, expect), "retrieve scored a stale key"
