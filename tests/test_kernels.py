"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs pure-jnp
oracle (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.dense_topk import (dense_topk_pallas,
                                      fused_gathered_topk_pallas,
                                      gathered_topk_pallas,
                                      quant_fused_gathered_topk_pallas,
                                      quant_gathered_topk_pallas,
                                      quant_topk_pallas)
from repro.retrieval.backends import quantize_kb


@pytest.mark.parametrize("B,N,d,k", [
    (1, 257, 32, 1), (4, 1000, 64, 8), (8, 4096, 128, 16),
    (3, 130, 16, 4), (16, 2048, 64, 32),
])
def test_dense_topk_matches_ref(B, N, d, k):
    kq, kk = jax.random.split(jax.random.PRNGKey(B * N + k))
    q = jax.random.normal(kq, (B, d), jnp.float32)
    kb = jax.random.normal(kk, (N, d), jnp.float32)
    s_k, i_k = dense_topk_pallas(q, kb, k, interpret=True)
    s_r, i_r = ref.dense_topk_ref(q, kb, k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_topk_dtypes(dtype):
    kq, kk = jax.random.split(jax.random.PRNGKey(7))
    q = jax.random.normal(kq, (4, 64)).astype(dtype)
    kb = jax.random.normal(kk, (512, 64)).astype(dtype)
    s_k, i_k = dense_topk_pallas(q, kb, 8, interpret=True)
    s_r, i_r = ref.dense_topk_ref(q, kb, 8)
    np.testing.assert_allclose(np.asarray(s_k, np.float32),
                               np.asarray(s_r, np.float32), atol=3e-2, rtol=3e-2)


def test_dense_topk_block_boundary_ids():
    """Ids crossing KB-tile boundaries must be globally correct."""
    d, N = 8, 700
    kb = np.zeros((N, d), np.float32)
    hot = [3, 255, 256, 511, 512, 699]
    for rank, idx in enumerate(hot):
        kb[idx, 0] = 10.0 - rank
    q = np.zeros((1, d), np.float32)
    q[0, 0] = 1.0
    s, i = dense_topk_pallas(jnp.asarray(q), jnp.asarray(kb), len(hot),
                             block_n=256, interpret=True)
    assert list(np.asarray(i[0])) == hot


# --------------------------------------------------------------------------------------
# int8 fused dequant+matmul+top-k kernels
# --------------------------------------------------------------------------------------
@pytest.mark.parametrize("B,N,d,k,block_n", [
    (1, 257, 32, 1, 1024), (4, 1000, 64, 8, 1024), (3, 130, 16, 4, 1024),
    (2, 700, 8, 6, 256),            # several KB tiles, ids cross boundaries
    (8, 2048, 64, 16, 512),
])
def test_quant_topk_matches_ref(B, N, d, k, block_n):
    kq, kk = jax.random.split(jax.random.PRNGKey(B * N + k))
    q = jax.random.normal(kq, (B, d), jnp.float32)
    codes, scales = quantize_kb(np.asarray(
        jax.random.normal(kk, (N, d), jnp.float32)))
    s_k, i_k = quant_topk_pallas(q, jnp.asarray(codes), jnp.asarray(scales),
                                 k, block_n=block_n, interpret=True)
    s_r, i_r = ref.quant_dense_topk_ref(q, jnp.asarray(codes),
                                        jnp.asarray(scales), k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))


@pytest.mark.parametrize("B,N,C,d,k,block_c", [
    (2, 300, 64, 16, 8, 512), (3, 500, 130, 32, 5, 64),
    (1, 128, 16, 8, 16, 512),       # k > real candidates -> pad sentinels
])
def test_quant_gathered_topk_matches_ref(B, N, C, d, k, block_c):
    """ADR-probe path: gathered int8 candidates + per-candidate scales, with
    ragged candidate rows (-1 padding) and block_c crossing tile boundaries."""
    ks = jax.random.split(jax.random.PRNGKey(N + C), 3)
    q = jax.random.normal(ks[0], (B, d), jnp.float32)
    codes, scales = quantize_kb(np.asarray(
        jax.random.normal(ks[1], (N, d), jnp.float32)))
    cand = np.full((B, C), -1, np.int64)
    g = np.random.default_rng(C)
    for b in range(B):
        w = int(g.integers(1, min(C, N)))
        cand[b, :w] = np.sort(g.choice(N, size=w, replace=False))
    safe = np.maximum(cand, 0)
    cand_emb = jnp.asarray(codes[safe])
    cand_scl = jnp.asarray(scales[safe])
    cand_j = jnp.asarray(cand, jnp.int32)
    s_k, i_k = quant_gathered_topk_pallas(q, cand_emb, cand_scl, cand_j, k,
                                          block_c=block_c, interpret=True)
    s_r, i_r = ref.quant_gathered_topk_ref(q, cand_emb, cand_scl, cand_j, k)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))
    # pad slots surface the canonical sentinels
    n_real = int((cand[0] >= 0).sum())
    if k > n_real:
        assert np.all(np.asarray(i_k)[0, n_real:] == -1)


# --------------------------------------------------------------------------------------
# fused in-kernel candidate gather (fp32 + int8): the tiled DMA path
# --------------------------------------------------------------------------------------
def _ragged_cand(g, B, C, N, dup_row=None, empty_row=None):
    """Id-sorted candidate rows with -1 tail padding; optionally one row with
    a duplicated real id and one all-pad row."""
    cand = np.full((B, C), -1, np.int64)
    for b in range(B):
        if b == empty_row:
            continue
        w = int(g.integers(1, min(C, N)))
        row = np.sort(g.choice(N, size=w, replace=False))
        if b == dup_row and w >= 2:
            row[1] = row[0]
        cand[b, :w] = row
    return cand


@pytest.mark.parametrize("B,N,C,d,k,block_c", [
    (2, 500, 130, 32, 5, 128),      # C not a multiple of 128; ragged tail tile
    (3, 300, 384, 16, 8, 128),      # ids cross gather-tile boundaries, 3 tiles
    (1, 128, 16, 8, 16, 256),       # k > real candidates -> pad sentinels
])
def test_fused_gathered_topk_matches_ref(B, N, C, d, k, block_c):
    """In-kernel DMA gather (interpret) vs the streaming jnp oracle."""
    ks = jax.random.split(jax.random.PRNGKey(N + C), 2)
    q = jax.random.normal(ks[0], (B, d), jnp.float32)
    kb = jax.random.normal(ks[1], (N, d), jnp.float32)
    cand = jnp.asarray(_ragged_cand(np.random.default_rng(C), B, C, N),
                       jnp.int32)
    s_k, i_k = fused_gathered_topk_pallas(q, kb, cand, k, block_c=block_c,
                                          interpret=True)
    s_r, i_r = ref.fused_gathered_topk_ref(q, kb, cand, k, block_c=block_c)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))


def test_fused_gathered_duplicates_and_allpad_rows():
    """Duplicate candidate ids tie-break to the earlier column (both paths);
    an all-pad row comes back entirely sentinel (NEG, -1)."""
    B, N, C, d, k = 3, 200, 140, 16, 6
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    q = jax.random.normal(ks[0], (B, d), jnp.float32)
    kb = jax.random.normal(ks[1], (N, d), jnp.float32)
    cand = jnp.asarray(
        _ragged_cand(np.random.default_rng(9), B, C, N, dup_row=0,
                     empty_row=2), jnp.int32)
    s_k, i_k = fused_gathered_topk_pallas(q, kb, cand, k, block_c=128,
                                          interpret=True)
    s_r, i_r = ref.fused_gathered_topk_ref(q, kb, cand, k, block_c=128)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))
    assert np.all(np.asarray(i_k)[2] == -1)           # all-pad row: sentinels
    assert np.all(np.asarray(s_k)[2] < -1e37)


def test_fused_gather_byte_parity_with_pregathered():
    """Fused in-kernel gather == pre-gathered (B, C, d) kernel, bit for bit,
    fp32 and int8 — the serve-path byte-parity invariant at kernel level."""
    B, N, C, d, k = 2, 300, 260, 16, 8
    g = np.random.default_rng(3)
    kb = (g.integers(-2, 3, size=(N, d)) / 2).astype(np.float32)
    q = jnp.asarray((g.integers(-2, 3, size=(B, d)) / 2).astype(np.float32))
    cand = _ragged_cand(g, B, C, N, empty_row=1)
    cand_j = jnp.asarray(cand, jnp.int32)
    safe = np.maximum(cand, 0)

    s_f, i_f = fused_gathered_topk_pallas(q, jnp.asarray(kb), cand_j, k,
                                          block_c=128, interpret=True)
    s_p, i_p = gathered_topk_pallas(q, jnp.asarray(kb[safe]), cand_j, k,
                                    interpret=True)
    assert np.array_equal(np.asarray(s_f), np.asarray(s_p))
    assert np.array_equal(np.asarray(i_f), np.asarray(i_p))

    codes, scales = quantize_kb(kb)
    s_qf, i_qf = quant_fused_gathered_topk_pallas(
        q, jnp.asarray(codes), jnp.asarray(scales), cand_j, k, block_c=128,
        interpret=True)
    s_qp, i_qp = quant_gathered_topk_pallas(
        q, jnp.asarray(codes[safe]), jnp.asarray(scales[safe]), cand_j, k,
        interpret=True)
    assert np.array_equal(np.asarray(s_qf), np.asarray(s_qp))
    assert np.array_equal(np.asarray(i_qf), np.asarray(i_qp))


@pytest.mark.parametrize("B,N,C,d,k,block_c", [
    (2, 500, 130, 32, 5, 128),      # C not a multiple of 128
    (1, 128, 16, 8, 16, 256),       # k > real candidates -> pad sentinels
    (3, 300, 270, 16, 6, 128),      # duplicate ids + tile-crossing rows
])
def test_quant_fused_gathered_topk_matches_ref(B, N, C, d, k, block_c):
    """int8 fused gather: codes AND per-row scales DMA in-kernel (interpret)
    vs the streaming oracle."""
    ks = jax.random.split(jax.random.PRNGKey(N + C + 1), 2)
    q = jax.random.normal(ks[0], (B, d), jnp.float32)
    codes, scales = quantize_kb(np.asarray(
        jax.random.normal(ks[1], (N, d), jnp.float32)))
    cand = jnp.asarray(
        _ragged_cand(np.random.default_rng(C + 1), B, C, N,
                     dup_row=0 if B > 2 else None), jnp.int32)
    s_k, i_k = quant_fused_gathered_topk_pallas(
        q, jnp.asarray(codes), jnp.asarray(scales), cand, k,
        block_c=block_c, interpret=True)
    s_r, i_r = ref.quant_fused_gathered_topk_ref(
        q, jnp.asarray(codes), jnp.asarray(scales), cand, k, block_c=block_c)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=1e-4,
                               rtol=1e-4)
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))


def test_quant_topk_block_boundary_ids():
    """Global ids stay correct when hot docs straddle int8 KB tiles."""
    d, N = 8, 700
    emb = np.zeros((N, d), np.float32)
    hot = [3, 255, 256, 511, 512, 699]
    for rank, idx in enumerate(hot):
        emb[idx, 0] = 10.0 - rank
    emb[:, 1] = 0.01                    # keep every row's scale positive
    codes, scales = quantize_kb(emb)
    q = np.zeros((1, d), np.float32)
    q[0, 0] = 1.0
    s, i = quant_topk_pallas(jnp.asarray(q), jnp.asarray(codes),
                             jnp.asarray(scales), len(hot),
                             block_n=256, interpret=True)
    assert list(np.asarray(i[0])) == hot


@pytest.mark.parametrize("B,H,KV,hd,W,cl", [
    (1, 4, 4, 32, 64, 64), (2, 8, 2, 32, 300, 123), (4, 16, 8, 64, 1024, 1000),
    (1, 8, 1, 128, 129, 57),
])
def test_decode_attention_matches_ref(B, H, KV, hd, W, cl):
    ks = jax.random.split(jax.random.PRNGKey(B + W), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
    cls = jnp.asarray([cl] + [max(1, cl // 2)] * (B - 1), jnp.int32)
    o_k = decode_attention_pallas(q, kc, vc, cls, block_w=128, interpret=True)
    o_r = ref.decode_attention_ref(q, kc, vc, cls)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_masks_invalid_slots():
    """Entries past cache_len must not influence the output."""
    B, H, KV, hd, W = 1, 2, 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, W, KV, hd))
    vc = jax.random.normal(ks[2], (B, W, KV, hd))
    cl = jnp.asarray([17], jnp.int32)
    o1 = decode_attention_pallas(q, kc, vc, cl, block_w=32, interpret=True)
    kc2 = kc.at[:, 17:].set(99.0)
    vc2 = vc.at[:, 17:].set(-99.0)
    o2 = decode_attention_pallas(q, kc2, vc2, cl, block_w=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_retriever_kernel_backend_agrees_with_numpy():
    """ExactDenseRetriever('kernel') == ExactDenseRetriever('numpy')."""
    from repro.retrieval.encoder import ContextEncoder
    from repro.retrieval.kb import DenseKB
    from repro.retrieval.retrievers import ExactDenseRetriever
    from repro.training.data import synthetic_corpus
    docs = synthetic_corpus(400, 512)
    enc = ContextEncoder(512, d=32)
    kb = DenseKB.build(docs, enc)
    r_np = ExactDenseRetriever(kb, backend="numpy")
    r_kn = ExactDenseRetriever(kb, backend="kernel")
    q = enc.encode_batch([d[:10] for d in docs[:3]])
    i1, s1 = r_np.retrieve(q, 5)
    i2, s2 = r_kn.retrieve(q, 5)
    np.testing.assert_allclose(s1, s2, atol=1e-4)
    assert np.array_equal(i1, i2)


# --------------------------------------------------------------------------------------
# prefill (flash) attention kernel
# --------------------------------------------------------------------------------------
from repro.kernels.prefill_attention import prefill_attention_pallas  # noqa: E402


@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,prefix", [
    (1, 128, 4, 2, 32, True, 0, 0),
    (2, 300, 4, 4, 16, True, 0, 0),
    (1, 257, 8, 2, 32, True, 64, 0),
    (1, 200, 4, 1, 32, True, 0, 37),      # prefix-LM (paligemma)
    (2, 160, 4, 2, 32, False, 0, 0),      # bidirectional (whisper encoder)
])
def test_prefill_attention_matches_ref(B, S, H, KV, hd, causal, window, prefix):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    o_k = prefill_attention_pallas(q, k, v, causal=causal, window=window,
                                   prefix_len=prefix, bq=64, bk=64,
                                   interpret=True)
    o_r = ref.prefill_attention_ref(q, k, v, causal=causal, window=window,
                                    prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5,
                               rtol=2e-5)


def test_prefill_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(jnp.bfloat16)
    o_k = prefill_attention_pallas(q, k, v, bq=64, bk=64, interpret=True)
    o_r = ref.prefill_attention_ref(q.astype(jnp.float32),
                                    k.astype(jnp.float32),
                                    v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(o_k, np.float32), np.asarray(o_r),
                               atol=5e-2, rtol=5e-2)
