"""The int8 quantized backend family's BOUNDED-RECALL CONTRACT — the tested
replacement for byte-parity once a backend sets ``exact = False``.

This module is the STATISTICAL layer — it needs no optional deps and runs in
every tier-1 cell: a deterministic seeded KB grid (random / clustered /
tie-heavy, several sizes) on which every int8 execution strategy (numpy
reference, fused kernel path, sharded mesh) must score recall@k >= 0.95 vs
FlatBackend, for the full scan AND the ADR-style gathered scan. The
hypothesis layers — quantize/dequantize round-trip properties and the
provable 2*eps bounded-miss theorem behind this floor — live in
tests/test_quantized_properties.py (skipped where hypothesis is absent; this
module is not).

Exact backends are provably unaffected: their classes carry ``exact = True``
and (test_backends / test_output_preservation) keep holding them to strict
byte-parity. Self-consistency of speculate+verify through an inexact backend
(fleet == RaLMSeq on the SAME backend) also lives in those two modules.
"""
import numpy as np
import pytest

from repro.retrieval.backends import (FlatBackend, QuantizedFlatBackend,
                                      make_backend)

# ---------------------------------------------------------------------------------
# deterministic KB grid (the statistical recall floor) — no hypothesis needed
# ---------------------------------------------------------------------------------


def _random_kb(rng, n, d):
    emb = rng.standard_normal((n, d)).astype(np.float32)
    return emb / np.linalg.norm(emb, axis=1, keepdims=True)


def _clustered_kb(rng, n, d, n_centers=8, spread=0.15):
    """Docs huddled around a few centers — the regime where quantized scores
    must separate near-neighbours within a cluster. (Spread matters: at
    ~0.05 the intra-cluster score gaps drop BELOW the int8 noise floor
    eps = (scale/2) * ||q||_1 and no per-row symmetric quantizer can hold
    0.95 — the bounded-miss theorem in test_quantized_properties.py is
    exactly the statement that only such sub-2*eps neighbours ever swap.)"""
    centers = rng.standard_normal((n_centers, d)).astype(np.float32)
    emb = (centers[rng.integers(0, n_centers, n)]
           + spread * rng.standard_normal((n, d)).astype(np.float32))
    return emb / np.linalg.norm(emb, axis=1, keepdims=True)


def _tie_heavy_kb(rng, n, d):
    """Mostly duplicate rows: identical rows quantize identically, so exact
    ties survive and the canonical id-asc order keeps recall whole."""
    base = _random_kb(rng, max(n // 8, 2), d)
    return np.tile(base, (-(-n // base.shape[0]), 1))[:n].copy()


_KB_GRID = [("random", _random_kb), ("clustered", _clustered_kb),
            ("tie-heavy", _tie_heavy_kb)]


def _recall_at_k(ids, ref_ids):
    hits = []
    for row, ref in zip(np.asarray(ids), np.asarray(ref_ids)):
        want = set(int(i) for i in ref if i >= 0)
        if want:
            hits.append(len(set(int(i) for i in row if i >= 0) & want)
                        / len(want))
    return float(np.mean(hits))


@pytest.mark.parametrize("kind,make_kb", _KB_GRID)
@pytest.mark.parametrize("backend", ["int8", "int8-kernel", "int8-sharded"])
def test_recall_contract_on_kb_grid(kind, make_kb, backend):
    """THE acceptance surface: every int8 execution strategy scores
    recall@k >= 0.95 vs FlatBackend on every KB kind, across sizes and
    batches (fixed seeds — a statistical claim needs a fixed sample, not a
    hypothesis search). The sharded cell collapses to one shard on the
    1-device CI leg; the program, not the shard count, is under test."""
    import jax
    n_shards = min(4, len(jax.devices()))
    recalls = []
    for n, d, k in [(256, 16, 8), (1024, 32, 10)]:
        # NOT hash(): str hashes are salted per process, and this claim needs
        # the same sample every run
        rng = np.random.default_rng((sum(kind.encode()) * 1000003 + n) % 2**31)
        emb = make_kb(rng, n, d)
        exact = FlatBackend(emb)
        quant = make_backend(backend, emb, n_shards=n_shards, force_ref=True)
        assert quant.exact is False and exact.exact is True
        for B in (1, 8):
            qs = _random_kb(rng, B, d)
            ref_ids, _ = exact.search(qs, k)
            ids, _ = quant.search(qs, k)
            recalls.append(_recall_at_k(ids, ref_ids))
    mean = float(np.mean(recalls))
    assert mean >= 0.95, f"{backend} on {kind}: mean recall {mean:.3f} < 0.95"
    if kind == "tie-heavy":
        # duplicates quantize identically, so ties survive and recall stays
        # (near-)whole — not exactly 1.0 by fiat, because BLAS may produce
        # position-dependent ulp differences for identical columns and flip
        # a boundary tie between the fp32 and int8 scans
        assert mean >= 0.99


@pytest.mark.parametrize("backend", ["int8", "int8-kernel", "int8-sharded"])
def test_gathered_recall_contract(backend):
    """The ADR probe's gathered scan meets the same floor: top-k of each
    row's candidate set, quantized vs exact."""
    import jax
    rng = np.random.default_rng(77)
    emb = _random_kb(rng, 512, 16)
    exact = FlatBackend(emb)
    quant = make_backend(backend, emb, n_shards=min(4, len(jax.devices())),
                        force_ref=True)
    cand = np.full((6, 64), -1, np.int64)
    for b in range(6):
        w = int(rng.integers(8, 64))
        cand[b, :w] = np.sort(rng.choice(512, size=w, replace=False))
    qs = _random_kb(rng, 6, 16)
    ref_ids, _ = exact.search_gathered(qs, cand, 8)
    ids, _ = quant.search_gathered(qs, cand, 8)
    assert _recall_at_k(ids, ref_ids) >= 0.95


def test_exact_backends_unaffected_and_memory_shrinks():
    """The capability bit tells the truth: fp32 backends stay exact = True
    and their search results are bit-identical to before the quantized
    family existed (FlatBackend IS the baseline); int8 halves-of-halves the
    index (> 3x smaller at d = 64, the serve default)."""
    rng = np.random.default_rng(5)
    emb = _random_kb(rng, 300, 64)
    flat, quant = FlatBackend(emb), QuantizedFlatBackend(emb)
    assert flat.exact is True and quant.exact is False
    assert flat.kb_bytes / quant.kb_bytes > 3
    # quantize_kb must not touch the caller's matrix
    assert emb is flat.embeddings and emb.dtype == np.float32
