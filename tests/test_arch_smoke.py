"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward + one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models.model import build_model, layer_plan, signatures
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.trainer import make_train_step

B, S = 2, 32


def _batch(cfg):
    g = np.random.default_rng(0)
    b = {"tokens": g.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    b["labels"] = b["tokens"].copy()
    if cfg.family == "audio":
        b["frames"] = g.standard_normal((B, cfg.encoder_frames, cfg.d_model)
                                        ).astype(np.float32) * 0.1
    if cfg.family == "vlm":
        b["patches"] = g.standard_normal((B, cfg.vision_patches, cfg.d_model)
                                         ).astype(np.float32) * 0.1
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe is None or cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    extra = {k: v for k, v in batch.items() if k in ("frames", "patches")} or None

    logits, aux = jax.jit(lambda p, t, e: model.forward(p, t, extra=e))(
        params, batch["tokens"], extra)
    S_out = S + (cfg.vision_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    params2, opt2, metrics = step(params, init_adamw(params), batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"])), f"{arch}: non-finite grads"
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_layer_plan_covers_all_layers(arch):
    cfg = get_config(arch)           # FULL config: plan structure, no allocation
    n_pre, period, n_rep = layer_plan(cfg)
    assert n_pre + period * n_rep == cfg.num_layers
    sigs = signatures(cfg)
    # every layer signature reachable through the plan
    for j in range(period):
        for r in range(n_rep):
            assert sigs[n_pre + r * period + j] == sigs[n_pre + j]


def test_full_config_param_counts():
    """Full configs match their nameplates (no allocation: analytic counts)."""
    expect = {"kimi-k2-1t-a32b": (1.0e12, 1.10e12), "qwen1.5-110b": (1.0e11, 1.2e11),
              "command-r-plus-104b": (1.0e11, 1.1e11), "jamba-v0.1-52b": (4.5e10, 5.5e10),
              "llama3.2-1b": (1.1e9, 1.4e9), "qwen3-4b": (3.8e9, 4.8e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3g} params outside [{lo:.3g},{hi:.3g}]"
