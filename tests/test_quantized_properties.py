"""Hypothesis property tests for the int8 quantize/dequantize round-trip and
the bounded-miss theorem behind the recall contract (the deterministic
recall-grid acceptance tests live in tests/test_quantized.py, which runs with
or without hypothesis).

Properties pinned here: scale positivity, max-abs preservation per row,
per-element dequant error <= scale/2, identical-row monotone ordering
(duplicates quantize identically, ties resolve id-ascending),
numpy-vs-jit quantized-scan parity on ids (scores within atol), and the
PROVABLE 2*eps bounded-miss theorem: with eps = (max_scale/2) * ||q||_1, a
doc whose exact score clears the selection boundary by more than 2*eps can
never be dropped by the quantized scan.
"""
import numpy as np
import pytest

from repro.retrieval.backends import QuantizedFlatBackend, quantize_kb

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@st.composite
def float_kb(draw):
    """Adversarial float KBs: mixed magnitudes per row (up to 1e3), so
    per-row scaling actually matters."""
    n = draw(st.integers(4, 48))
    d = draw(st.sampled_from([3, 8, 17]))
    seed = draw(st.integers(0, 10_000))
    g = np.random.default_rng(seed)
    mag = 10.0 ** g.uniform(-2, 3, size=(n, 1))
    emb = (g.standard_normal((n, d)) * mag).astype(np.float32)
    q = g.standard_normal(d).astype(np.float32)
    return emb, q


@given(float_kb())
@settings(max_examples=80, deadline=None)
def test_roundtrip_scale_and_error_bounds(case):
    """Scales strictly positive; 127*scale recovers each row's max-abs to a
    few ulp; per-element dequant error <= scale/2 (+ float slack); codes
    never exceed the symmetric range."""
    emb, _ = case
    codes, scales = quantize_kb(emb)
    assert codes.dtype == np.int8 and scales.dtype == np.float32
    assert np.all(scales > 0)
    assert np.all(np.abs(codes.astype(np.int32)) <= 127)
    maxabs = np.abs(emb).max(axis=1)
    np.testing.assert_allclose(127.0 * scales, np.maximum(maxabs, 1e-12),
                               rtol=1e-5)
    deq = codes.astype(np.float32) * scales[:, None]
    err = np.abs(deq - emb)
    assert np.all(err <= 0.5 * scales[:, None] * (1 + 1e-5) + 1e-30)


@given(float_kb(), st.integers(0, 1000))
@settings(max_examples=60, deadline=None)
def test_duplicated_rows_quantize_identically(case, seed):
    """Duplicate a random row over the KB: all copies must get identical
    codes AND scales (quantization is a pure per-row function). The monotone
    id-ascending ordering of the tied duplicates is then asserted on a
    grid-quantized KB, where every dot is exactly representable — on
    arbitrary floats BLAS legitimately yields position-dependent ulp
    differences for identical columns, so 'identical rows' only implies
    'exactly tied scores' when the arithmetic is exact."""
    emb, q = case
    g = np.random.default_rng(seed)
    src = int(g.integers(0, emb.shape[0]))
    dupes = sorted(set(g.integers(0, emb.shape[0], 5).tolist()) | {src})
    emb = emb.copy()
    emb[dupes] = emb[src]
    codes, scales = quantize_kb(emb)
    for i in dupes:
        assert np.array_equal(codes[i], codes[src]) and scales[i] == scales[src]
    emb_g = np.clip(np.rint(emb), -8, 8).astype(np.float32) / 2.0
    emb_g[dupes] = emb_g[src]
    q_g = np.clip(np.rint(q), -6, 6).astype(np.float32) / 2.0
    ids, scores = QuantizedFlatBackend(emb_g).search(q_g[None], len(dupes))
    got = [int(i) for i in ids[0] if int(i) in dupes]
    assert got == sorted(got), "tied duplicates must come back id-ascending"


@given(float_kb())
@settings(max_examples=50, deadline=None)
def test_numpy_vs_jit_quantized_scan_parity(case):
    """kernel == numpy-quantized on ids (scores within atol): both paths
    score the SAME codes with the same operation order, so any id split can
    only come from summation-order ulp on genuinely near-tied scores — on
    grid-quantized queries (multiples of 1/2) even those vanish and ids must
    match exactly."""
    from repro.kernels.ops import quant_dense_topk
    emb, q = case
    d = emb.shape[1]
    g = np.random.default_rng(int(abs(emb[0, 0]) * 1e3) % 997)
    qs = (g.integers(-6, 7, size=(3, d)) / 2.0).astype(np.float32)
    # grid-quantize the KB too: products & partial sums exactly representable
    emb_g = np.clip(np.rint(emb), -8, 8).astype(np.float32) / 2.0
    codes, scales = quantize_kb(emb_g)
    k = min(5, emb.shape[0])
    ni, ns = QuantizedFlatBackend(emb_g).search(qs, k)
    js, ji = quant_dense_topk(qs, codes, scales, k, force_ref=True)
    assert np.array_equal(ni, np.asarray(ji, np.int64))
    np.testing.assert_allclose(ns, np.asarray(js), atol=1e-5, rtol=1e-5)


@given(float_kb(), st.integers(1, 8))
@settings(max_examples=80, deadline=None)
def test_bounded_miss_theorem(case, k):
    """The provable core of the recall contract. Per-element dequant error
    <= scale/2 bounds every doc's score error by
    eps = (max_scale / 2) * ||q||_1; hence any exact-top-k doc the quantized
    top-k misses has exact score within 2*eps of the LOWEST selected doc's
    exact score. Quantization can only swap near-equals — a doc separated
    from the boundary by more than 2*eps can never be dropped."""
    emb, q = case
    k = min(k, emb.shape[0])
    codes, scales = quantize_kb(emb)
    exact_scores = (emb @ q).astype(np.float64)
    ids, _ = QuantizedFlatBackend(emb).search(q[None], k)
    sel = set(int(i) for i in ids[0])
    eps = float(scales.max()) / 2.0 * float(np.abs(q).sum())
    boundary = min(exact_scores[i] for i in sel)
    missed = [i for i in np.argsort(-exact_scores)[:k] if i not in sel]
    slack = 2.0 * eps * (1 + 1e-5) + 1e-5
    for m in missed:
        assert exact_scores[m] <= boundary + slack, \
            (f"doc {m} (exact {exact_scores[m]:.6g}) dropped though "
             f"{exact_scores[m] - boundary:.3g} above the boundary; "
             f"2*eps = {2 * eps:.3g}")
