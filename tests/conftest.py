import os
import sys

# tests run on CPU with a simulated 4-device host platform so the sharded
# retrieval backend's collectives execute over a real (forced) multi-device
# mesh in the fast tier; both flags must be set before jax initializes. The
# 512-device dry-run override remains subprocess-only (repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4").strip()
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
