import os
import sys

# tests must see the single real CPU device (the 512-device override is applied by
# repro.launch.dryrun only, in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
