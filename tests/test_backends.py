"""The retrieval-backend layer (repro.retrieval.backends): the three execution
strategies — flat numpy scan, Pallas blocked top-k (interpret on CPU), and the
mesh-sharded collective — return BYTE-IDENTICAL (ids, scores) under the
canonical tie order (score desc, id asc), across batch sizes, k values,
tie-heavy KBs, and KB sizes that don't divide the shard count; and the serving
paths reach the sharded backend with exactly ONE collective per KB call.

The int8 quantized family (int8 / int8-kernel / int8-sharded) is tested to a
different contract — mutual parity within the family (one shared quantization)
plus SELF-consistency through the serving paths (fleet == RaLMSeq on the same
inexact backend) and the same one-collective ledger; its recall-vs-fp32
contract lives in tests/test_quantized.py.

Cross-backend byte-equality is only meaningful when the scores themselves are
bit-equal across numpy-BLAS and XLA reductions, so the parity KBs use
grid-quantized embeddings (entries in multiples of 1/2, d small): every dot
product is exactly representable in float32 regardless of summation order.
The conftest forces a 4-device CPU host platform, so the sharded backend's
collectives run over a real multi-device mesh in the fast tier.
"""
import jax
import numpy as np
import pytest

from repro.retrieval.backends import (FlatBackend, KernelBackend,
                                      ShardedBackend, canonical_topk,
                                      make_backend)
from repro.retrieval.kb import DenseKB
from repro.retrieval.retrievers import (ExactDenseRetriever, IVFRetriever,
                                        RetrieverStats)


def _grid(rng, n, d):
    """Embeddings whose pairwise dots are exact in f32 for any summation order."""
    return rng.integers(-2, 3, size=(n, d)).astype(np.float32) / 2


def _tie_heavy(rng, n, d):
    """A KB where most rows are duplicates: exact score ties everywhere."""
    base = _grid(rng, max(n // 8, 2), d)
    return np.tile(base, (-(-n // base.shape[0]), 1))[:n]


@pytest.fixture(scope="module")
def four_devices():
    if len(jax.devices()) < 4:
        pytest.skip("needs the forced 4-device CPU platform (conftest)")
    return 4


# ---------------------------------------------------------------------------------
# pure backend parity
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("n,d", [(96, 16), (130, 8), (257, 32)])
@pytest.mark.parametrize("ties", [False, True])
def test_backend_parity_byte_identical(four_devices, n, d, ties):
    """numpy == kernel(interpret) == sharded, ids AND scores, bit for bit —
    including KB sizes that don't divide the 4-shard mesh (130, 257) and
    tie-heavy KBs where only the canonical order keeps results unique."""
    rng = np.random.default_rng(n + d + ties)
    emb = _tie_heavy(rng, n, d) if ties else _grid(rng, n, d)
    flat = FlatBackend(emb)
    kern = KernelBackend(emb)
    shard = ShardedBackend(emb, n_shards=4)
    assert shard.n_shards == 4
    for B in (1, 3, 8):
        qs = _grid(rng, B, d)
        for k in (1, 5, 40):
            fi, fs = flat.search(qs, k)
            ki, ks = kern.search(qs, k)
            si, ss = shard.search(qs, k)
            assert fi.shape == (B, min(k, n)) and fs.dtype == np.float32
            assert np.array_equal(fi, ki), f"B={B} k={k}: flat vs kernel ids"
            assert np.array_equal(fs, ks), f"B={B} k={k}: flat vs kernel scores"
            assert np.array_equal(fi, si), f"B={B} k={k}: flat vs sharded ids"
            assert np.array_equal(fs, ss), f"B={B} k={k}: flat vs sharded scores"


def test_backend_k_exceeds_kb_size(four_devices):
    """k > N clamps to N identically on every backend (the KNN-LM path asks
    for knn_k neighbours of arbitrarily small reduced datastores)."""
    rng = np.random.default_rng(5)
    emb = _grid(rng, 12, 8)
    q = _grid(rng, 2, 8)
    fi, fs = FlatBackend(emb).search(q, 50)
    kern = KernelBackend(emb)
    ki, ks = kern.search(q, 50)
    si, ss = ShardedBackend(emb, n_shards=4).search(q, 50)
    assert fi.shape == ki.shape == si.shape == (2, 12)
    assert np.array_equal(fi, ki) and np.array_equal(fi, si)
    assert np.array_equal(fs, ks) and np.array_equal(fs, ss)
    # the compile cache keys on the CLAMPED k: k=50 and k=12 run the same
    # compiled program, so recording one must mark the other warm
    assert kern.cold_shape(2, 50) is True
    assert kern.cold_shape(2, 12) is False


def test_sharded_one_collective_per_search(four_devices):
    rng = np.random.default_rng(0)
    shard = ShardedBackend(_grid(rng, 100, 16), n_shards=4)
    for i in range(3):
        shard.search(_grid(rng, 2, 16), 4)
    assert shard.calls == 3


def test_sharded_nondivisible_masks_padding(four_devices):
    """100 % 4 == 0 but 97 % 4 != 0: padded rows must never surface, even when
    every real score is negative (a zero-padded row would otherwise win)."""
    rng = np.random.default_rng(1)
    emb = -np.abs(_grid(rng, 97, 8)) - 0.5        # all dots with +q negative
    q = np.abs(_grid(rng, 2, 8)) + 0.5
    si, ss = ShardedBackend(emb, n_shards=4).search(q, 97)
    assert si.max() < 97 and si.min() >= 0
    assert np.array_equal(np.sort(si, axis=1), np.tile(np.arange(97), (2, 1)))
    fi, fs = FlatBackend(emb).search(q, 97)
    assert np.array_equal(fi, si) and np.array_equal(fs, ss)


def test_canonical_topk_tie_order():
    """Ties resolve score desc then id ASC — including boundary ties, where
    argpartition alone would pick arbitrary members of the tied set."""
    s = np.array([[1.0, 2.0, 2.0, 0.5, 2.0, 1.0]], np.float32)
    ids, sc = canonical_topk(s, 4)
    assert ids.tolist() == [[1, 2, 4, 0]]
    assert sc.tolist() == [[2.0, 2.0, 2.0, 1.0]]
    # all-equal row: top-k is the k lowest ids
    ids, _ = canonical_topk(np.ones((1, 9), np.float32), 3)
    assert ids.tolist() == [[0, 1, 2]]


def test_make_backend_names():
    from repro.retrieval.backends import BACKENDS
    emb = _grid(np.random.default_rng(2), 32, 8)
    for name in BACKENDS:
        b = make_backend(name, emb, n_shards=2)
        assert b.name == name
        # the capability bit the preservation matrix keys on: fp32 strategies
        # are exact (byte-parity contractual), int8 strategies are not
        assert b.exact is (not name.startswith("int8"))
        assert b.kb_bytes > 0
    with pytest.raises(KeyError):
        make_backend("faiss", emb)


def test_quantized_trio_mutual_parity(four_devices):
    """int8 == int8-kernel == int8-sharded on ids AND scores — all three
    score the SAME code matrix (one host-side quantize_kb) with the same
    operation order, so within the quantized family the cross-strategy
    byte-parity discipline survives. Scores compare within atol only: the
    numpy path sums via BLAS, the jit paths via XLA."""
    rng = np.random.default_rng(21)
    for n, d in [(96, 16), (130, 8)]:
        emb = _grid(rng, n, d)
        flat = make_backend("int8", emb)
        kern = make_backend("int8-kernel", emb)
        shard = make_backend("int8-sharded", emb, n_shards=4)
        assert shard.n_shards == 4
        for B in (1, 3):
            qs = _grid(rng, B, d)
            for k in (1, 5, 40):
                fi, fs = flat.search(qs, k)
                ki, ks = kern.search(qs, k)
                si, ss = shard.search(qs, k)
                tag = f"n={n} B={B} k={k}"
                assert np.array_equal(fi, ki), f"{tag}: int8 vs int8-kernel"
                assert np.array_equal(fi, si), f"{tag}: int8 vs int8-sharded"
                np.testing.assert_allclose(fs, ks, atol=1e-5, rtol=1e-5)
                np.testing.assert_allclose(fs, ss, atol=1e-5, rtol=1e-5)


def test_int8_sharded_one_collective_per_search(four_devices):
    """The quantized mesh keeps the collective ledger: one per search, dense
    and gathered alike."""
    rng = np.random.default_rng(23)
    shard = make_backend("int8-sharded", _grid(rng, 100, 16), n_shards=4)
    for i in range(3):
        shard.search(_grid(rng, 2, 16), 4)
    cand = np.sort(rng.choice(100, size=(2, 10), replace=False), axis=1)
    shard.search_gathered(_grid(rng, 2, 16), cand.astype(np.int64), 4)
    assert shard.calls == 4


# ---------------------------------------------------------------------------------
# ADR: the IVF probe through the same backend layer (gathered/masked scan)
# ---------------------------------------------------------------------------------
def _ivf_kb(emb):
    return DenseKB(embeddings=emb, docs=[[0]] * emb.shape[0])


def _adr_trio(emb, *, n_clusters=8, nprobe=2):
    """Three IVFRetrievers over identical clusterings (same seed), one per
    execution backend."""
    kb = _ivf_kb(emb)
    return {be: IVFRetriever(kb, n_clusters=n_clusters, nprobe=nprobe,
                             backend=be, mesh_shards=4)
            for be in ("numpy", "kernel", "sharded")}


@pytest.mark.parametrize("n,d", [(96, 16), (130, 8), (257, 32)])
@pytest.mark.parametrize("ties", [False, True])
def test_adr_backend_parity_byte_identical(four_devices, n, d, ties):
    """The IVF probe returns byte-identical ids AND scores on every backend —
    across batch sizes, k values, nprobe, tie-heavy KBs, and KB sizes that
    make bucket sizes non-divisible by anything in sight. Bucket membership
    is fixed by the (shared-seed) clustering, so only the gathered scan's
    execution differs."""
    rng = np.random.default_rng(100 + n + d + ties)
    emb = _tie_heavy(rng, n, d) if ties else _grid(rng, n, d)
    for nprobe in (1, 3):
        retrs = _adr_trio(emb, nprobe=nprobe)
        assert retrs["sharded"].backend.n_shards == 4
        for B in (1, 3, 8):
            qs = _grid(rng, B, d)
            for k in (1, 5, 40):
                ni, ns = retrs["numpy"].retrieve(qs, k)
                ki, ks = retrs["kernel"].retrieve(qs, k)
                si, ss = retrs["sharded"].retrieve(qs, k)
                assert ni.shape == (B, k) and ns.dtype == np.float32
                tag = f"nprobe={nprobe} B={B} k={k}"
                assert np.array_equal(ni, ki), f"{tag}: numpy vs kernel ids"
                assert np.array_equal(ns, ks), f"{tag}: numpy vs kernel scores"
                assert np.array_equal(ni, si), f"{tag}: numpy vs sharded ids"
                assert np.array_equal(ns, ss), f"{tag}: numpy vs sharded scores"


def test_adr_canonical_tie_order(four_devices):
    """Score ties in the probed buckets resolve id-ASCENDING on every backend
    (all-duplicate KB: every candidate scores identically, so the top-k must
    be each row's lowest probed ids)."""
    rng = np.random.default_rng(7)
    emb = np.tile(_grid(rng, 1, 8), (64, 1))          # 64 identical rows
    qs = _grid(rng, 4, 8)
    want = None
    for be, r in _adr_trio(emb, n_clusters=4, nprobe=2).items():
        ids, sc = r.retrieve(qs, 6)
        for b in range(4):
            row = ids[b]
            assert list(row) == sorted(row), f"{be}: ties not id-ascending"
        if want is None:
            want = ids
        assert np.array_equal(ids, want), be


@pytest.mark.parametrize("width", [12, 700])
def test_adr_gathered_pad_slots_are_sentinels(four_devices, width):
    """At the BACKEND level, slots beyond a row's real candidate count come
    back as (id=-1, score=-inf) on every backend — the retriever's
    repeat-last fill is layered on top, identically everywhere. width=700
    spans multiple Pallas tiles (block_c=512): the streaming top-k must keep
    emitting pad sentinels on later grid steps, not echo ids it already
    extracted (regression: _select_topk once masked only the score of a
    picked slot, so exhausted rows re-picked position 0 and duplicated the
    running best id)."""
    rng = np.random.default_rng(3)
    emb = _grid(rng, 40, 8)
    qs = _grid(rng, 2, 8)
    cand = np.full((2, width), -1, np.int64)
    cand[0, :5] = [2, 7, 11, 30, 39]                  # 5 real candidates
    cand[1, :1] = [4]                                 # 1 real candidate
    k = 8
    for name, be in [("numpy", FlatBackend(emb)),
                     ("kernel", KernelBackend(emb)),
                     ("sharded", ShardedBackend(emb, n_shards=4)),
                     ("int8", make_backend("int8", emb)),
                     ("int8-kernel", make_backend("int8-kernel", emb)),
                     ("int8-sharded", make_backend("int8-sharded", emb,
                                                   n_shards=4))]:
        ids, sc = be.search_gathered(qs, cand, k)
        assert ids.shape == (2, 8), name
        assert np.all(ids[0, 5:] == -1) and np.all(ids[1, 1:] == -1), name
        assert np.all(np.isneginf(sc[0, 5:])), name
        assert np.all(np.isneginf(sc[1, 1:])), name
        assert np.all(ids[0, :5] >= 0) and ids[1, 0] == 4, name


def test_adr_sharded_one_collective_per_probe(four_devices):
    """Every ADR retrieve (the merged probe, any batch width) is exactly ONE
    sharded collective: centroid scoring stays host-side."""
    rng = np.random.default_rng(11)
    r = IVFRetriever(_ivf_kb(_grid(rng, 130, 16)), n_clusters=8, nprobe=2,
                     backend="sharded", mesh_shards=4)
    for B in (1, 4, 7):
        r.retrieve(_grid(rng, B, 16), 5)
    assert r.backend.calls == 3 == r.stats.calls


def test_adr_jitted_backend_warmup_keys_on_candidate_width():
    """ADR's compiled probe is shaped by (B, C, k); the first call per shape
    is flagged warmup and excluded from the latency-unit EMA, later calls at
    the same shape are warm. The numpy backend never warms up. (kernel-only:
    runs on the single-device CI matrix leg too.)"""
    rng = np.random.default_rng(13)
    kb = _ivf_kb(_grid(rng, 120, 16))
    r = IVFRetriever(kb, n_clusters=8, nprobe=2, backend="kernel")
    q = _grid(rng, 1, 16)
    r.retrieve(q, 4)
    assert r.stats.warmup_calls == 1 and r.stats.model_latency(1) == 0.0
    r.retrieve(q, 4)                        # warm shape: calibrates now
    assert r.stats.warmup_calls == 1 and r.stats.model_latency(1) > 0.0
    r.retrieve(_grid(rng, 2, 16), 4)        # new batch shape: warmup again
    assert r.stats.warmup_calls == 2
    rn = IVFRetriever(kb, n_clusters=8, nprobe=2)
    rn.retrieve(q, 4)
    assert rn.stats.warmup_calls == 0 and rn.stats.model_latency(1) > 0.0


def test_candidate_scratch_accounting_fused_vs_pregathered():
    """The fused in-kernel gather's peak candidate buffer is one
    (B, block_c, ...) tile — independent of C — for the kernel/sharded
    families: at the acceptance point (C=4096, d=64) the pre-gathered
    (B, C, ...) slab is >= 10x larger, fp32 and int8 alike. The flat hosts
    chunk their gather, so they too never exceed the pre-gathered slab."""
    rng = np.random.default_rng(31)
    emb = _grid(rng, 256, 64)
    B, C = 8, 4096
    for name in ("kernel", "sharded", "int8-kernel", "int8-sharded"):
        b = make_backend(name, emb, n_shards=2)
        got = b.gathered_scratch_bytes(B, C)
        pre = b.pregathered_scratch_bytes(B, C)
        assert got > 0 and pre > 0, name
        assert got * 10 <= pre, f"{name}: only {pre / got:.1f}x < 10x"
    b = make_backend("numpy", emb)
    assert b.gathered_scratch_bytes(B, C) <= b.pregathered_scratch_bytes(B, C)
    # the int8 HOST path casts row chunks to fp32, so its honest peak can
    # exceed the naive int8 (B, C, d+4) slab — but never the full fp32 cast
    b = make_backend("int8", emb)
    assert 0 < b.gathered_scratch_bytes(B, C) <= B * C * emb.shape[1] * 4
    # a custom tile width moves the fused families' accounting
    wide = make_backend("kernel", emb, block_c=1024)
    assert wide.gathered_scratch_bytes(B, C) \
        > make_backend("kernel", emb).gathered_scratch_bytes(B, C)


# ---------------------------------------------------------------------------------
# stats calibration hygiene (warmup exclusion)
# ---------------------------------------------------------------------------------
def test_stats_warmup_excluded_from_unit():
    stats = RetrieverStats("const")
    stats.add(1, 5.0, warmup=True)          # compile-polluted sample
    assert stats.calls == 1 and stats.warmup_calls == 1
    assert stats.model_latency(1) == 0.0    # unit still uncalibrated
    stats.add(1, 1e-3)
    assert abs(stats.model_latency(1) - 1e-3) < 1e-12
    stats.add(4, 9.0, warmup=True)          # batch-shape compile: also excluded
    assert abs(stats.model_latency(1) - 1e-3) < 1e-12
    assert stats.calls == 3 and stats.queries == 6


def test_jitted_retriever_first_call_per_shape_is_warmup():
    """EDR over a jitted backend flags the first call of each (B, k) shape as
    warmup; the numpy backend never does."""
    from repro.retrieval.encoder import ContextEncoder
    from repro.retrieval.kb import DenseKB
    from repro.training.data import synthetic_corpus
    docs = synthetic_corpus(120, 256)
    enc = ContextEncoder(256, d=16)
    kb = DenseKB.build(docs, enc)
    q = enc.encode(docs[0][:8])
    r = ExactDenseRetriever(kb, backend="kernel")
    r.retrieve(q[None], 4)
    assert r.stats.warmup_calls == 1 and r.stats.model_latency(1) == 0.0
    r.retrieve(q[None], 4)                  # warm shape: calibrates now
    assert r.stats.warmup_calls == 1 and r.stats.model_latency(1) > 0.0
    unit = r.stats.model_latency(1)
    r.retrieve(np.stack([q, q]), 4)         # new batch shape: warmup again
    assert r.stats.warmup_calls == 2
    assert r.stats.model_latency(1) == unit
    rn = ExactDenseRetriever(kb)            # numpy: no warmup ever
    rn.retrieve(q[None], 4)
    assert rn.stats.warmup_calls == 0 and rn.stats.model_latency(1) > 0.0
    # the compile cache lives on the BACKEND: a second retriever sharing r's
    # backend sees its shapes as already warm and calibrates immediately
    r2 = ExactDenseRetriever(kb, backend=r.backend)
    r2.retrieve(q[None], 4)
    assert r2.stats.warmup_calls == 0 and r2.stats.model_latency(1) > 0.0


def test_mesh_shards_malformed_value_is_argparse_error():
    """A bad --mesh-shards must surface as argparse's clean 'invalid int'
    (exit 2), not an import-time traceback from the pre-jax bootstrap."""
    import os
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--mesh-shards", "four"],
        cwd=root, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 2, out.stderr[-1500:]
    assert "invalid int value" in out.stderr
    assert "Traceback" not in out.stderr


# ---------------------------------------------------------------------------------
# serving parity: the fleet's merged verification through the sharded mesh
# ---------------------------------------------------------------------------------
# NB: unlike the pure-parity tests above, the serve stack uses the real
# ContextEncoder (non-exact float arithmetic), so what these assert is the
# paper's output-preservation surface — served TOKENS identical across
# backends, which only needs cross-backend top-1 agreement — not bitwise
# score equality (that claim is only made, and only tested, on the
# grid-quantized KBs).
@pytest.fixture(scope="module")
def serve_stack():
    from repro.configs import get_config, reduced
    from repro.models.model import build_model
    from repro.retrieval.encoder import ContextEncoder
    from repro.retrieval.kb import DenseKB
    from repro.serving.batched import BatchedServeEngine
    from repro.serving.engine import ServeEngine
    from repro.training.data import make_queries, synthetic_corpus
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(900, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 3)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 3, cache_window=256)
    return docs, enc, dkb, prompts, seng, beng


def _rcfg(**kw):
    from repro.configs import RaLMConfig
    return RaLMConfig(max_new_tokens=15, speculation_stride=3, **kw)


def _seq_tokens(serve_stack):
    from repro.core.ralmspec import RaLMSeq
    docs, enc, dkb, prompts, seng, beng = serve_stack
    retr = ExactDenseRetriever(dkb)
    return [RaLMSeq(seng, retr, _rcfg(), enc).serve(p).tokens for p in prompts]


@pytest.mark.parametrize("async_rounds", [False, True])
def test_sharded_fleet_serve_parity(four_devices, serve_stack, async_rounds):
    """Fleet-served EDR through the sharded mesh == per-request RaLMSeq, sync
    and async/pipelined, with exactly one sharded collective per verification
    round (plus the one seed call)."""
    from repro.serving.fleet import FleetServer
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _seq_tokens(serve_stack)
    retr = ExactDenseRetriever(dkb, backend="sharded", mesh_shards=4)
    assert retr.backend.n_shards == 4
    with FleetServer(beng, retr, _rcfg(), enc,
                     async_rounds=async_rounds) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == want, \
        "sharded-backend fleet diverged from per-request RaLMSeq"
    # the merge invariant through the mesh: every KB call the fleet issued
    # (1 seed + 1 merged verification per round) was ONE collective
    assert retr.backend.calls == fr.kb_calls == fr.rounds + 1


def test_sharded_continuous_serve_parity(four_devices, serve_stack):
    """Continuous batching through the sharded mesh: byte-identical outputs
    under churn, still one collective per KB call."""
    from repro.serving.continuous import ContinuousFleetServer, as_requests
    from repro.serving.batched import BatchedServeEngine
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _seq_tokens(serve_stack)
    retr = ExactDenseRetriever(dkb, backend="sharded", mesh_shards=4)
    eng2 = BatchedServeEngine(beng.model, beng.params, 2, cache_window=256)
    server = ContinuousFleetServer(eng2, retr, _rcfg(), enc)
    cr = server.serve(as_requests(prompts, [0.0, 0.0, 1.0]))
    assert [r.tokens for r in cr.results] == want, \
        "sharded-backend continuous fleet diverged from per-request RaLMSeq"
    assert retr.backend.calls == retr.stats.calls


def _adr_retr(dkb, backend="numpy"):
    # identical clustering on every backend (shared seed); small index so the
    # probes actually miss sometimes and rollbacks exercise the restore path
    return IVFRetriever(dkb, n_clusters=16, nprobe=2, backend=backend,
                        mesh_shards=4)


def _adr_seq_tokens(serve_stack):
    from repro.core.ralmspec import RaLMSeq
    docs, enc, dkb, prompts, seng, beng = serve_stack
    retr = _adr_retr(dkb)
    return [RaLMSeq(seng, retr, _rcfg(), enc).serve(p).tokens for p in prompts]


@pytest.mark.parametrize("async_rounds", [False, True])
def test_adr_sharded_fleet_serve_parity(four_devices, serve_stack,
                                        async_rounds):
    """Fleet-served ADR through the sharded mesh == per-request RaLMSeq over
    the numpy IVF probe, sync and async/pipelined, with exactly ONE sharded
    collective per merged probe round (plus the one seed call) — the
    acceptance surface for routing the IVF probe through the backend layer."""
    from repro.serving.fleet import FleetServer
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _adr_seq_tokens(serve_stack)
    retr = _adr_retr(dkb, backend="sharded")
    assert retr.backend.n_shards == 4
    with FleetServer(beng, retr, _rcfg(), enc,
                     async_rounds=async_rounds) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == want, \
        "sharded-backend ADR fleet diverged from per-request RaLMSeq"
    assert retr.backend.calls == fr.kb_calls == fr.rounds + 1


def test_adr_sharded_continuous_serve_parity(four_devices, serve_stack):
    """Continuous batching over the sharded ADR probe: byte-identical outputs
    under churn, one collective per KB call."""
    from repro.serving.continuous import ContinuousFleetServer, as_requests
    from repro.serving.batched import BatchedServeEngine
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _adr_seq_tokens(serve_stack)
    retr = _adr_retr(dkb, backend="sharded")
    eng2 = BatchedServeEngine(beng.model, beng.params, 2, cache_window=256)
    server = ContinuousFleetServer(eng2, retr, _rcfg(), enc)
    cr = server.serve(as_requests(prompts, [0.0, 0.0, 1.0]))
    assert [r.tokens for r in cr.results] == want, \
        "sharded-backend ADR continuous fleet diverged from RaLMSeq"
    assert retr.backend.calls == retr.stats.calls


@pytest.mark.parametrize("async_rounds", [False, True])
def test_adr_kernel_fleet_serve_parity(serve_stack, async_rounds):
    """The fused in-kernel gather (interpret-mode Pallas / streaming oracle)
    serves the same tokens too — the kernel cell of the ADR x backend matrix,
    sync and async/pipelined, one backend call per merged probe round (plus
    the seed call). (kernel-only: runs on the single-device CI matrix leg
    too.)"""
    from repro.serving.fleet import FleetServer
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _adr_seq_tokens(serve_stack)
    retr = _adr_retr(dkb, backend="kernel")
    with FleetServer(beng, retr, _rcfg(), enc,
                     async_rounds=async_rounds) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == want
    assert retr.backend.calls == fr.kb_calls == fr.rounds + 1


def test_adr_kernel_continuous_serve_parity(serve_stack):
    """Continuous batching over the fused kernel ADR probe: byte-identical
    outputs under churn, one backend call per KB call. (kernel-only: runs on
    the single-device CI matrix leg too.)"""
    from repro.serving.continuous import ContinuousFleetServer, as_requests
    from repro.serving.batched import BatchedServeEngine
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = _adr_seq_tokens(serve_stack)
    retr = _adr_retr(dkb, backend="kernel")
    eng2 = BatchedServeEngine(beng.model, beng.params, 2, cache_window=256)
    server = ContinuousFleetServer(eng2, retr, _rcfg(), enc)
    cr = server.serve(as_requests(prompts, [0.0, 0.0, 1.0]))
    assert [r.tokens for r in cr.results] == want, \
        "kernel-backend ADR continuous fleet diverged from RaLMSeq"
    assert retr.backend.calls == retr.stats.calls


@pytest.mark.parametrize("async_rounds", [False, True])
def test_int8_kernel_adr_fleet_self_consistency(serve_stack, async_rounds):
    """The int8 fused gather's preservation surface: fleet-served ADR through
    the int8-kernel backend == per-request RaLMSeq on the SAME backend (codes
    AND per-row scales DMA in-kernel; determinism is the contract), with one
    backend call per merged probe round plus the seed call."""
    from repro.core.ralmspec import RaLMSeq
    from repro.serving.fleet import FleetServer
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = [RaLMSeq(seng, _adr_retr(dkb, backend="int8-kernel"), _rcfg(),
                    enc).serve(p).tokens for p in prompts]
    retr = _adr_retr(dkb, backend="int8-kernel")
    assert retr.backend.exact is False
    with FleetServer(beng, retr, _rcfg(), enc,
                     async_rounds=async_rounds) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == want, \
        "int8-kernel ADR fleet diverged from RaLMSeq on the same backend"
    assert retr.backend.calls == fr.kb_calls == fr.rounds + 1


@pytest.mark.parametrize("async_rounds", [False, True])
def test_int8_sharded_fleet_serve_self_consistency(four_devices, serve_stack,
                                                   async_rounds):
    """The INEXACT contract's preservation surface: fleet-served EDR through
    the int8 mesh == per-request RaLMSeq through the SAME int8 backend (the
    speculate+verify loop needs determinism, not exactness — both paths see
    one and the same quantized scan), with exactly one collective per
    verification round (plus the seed call). The fp32-baseline byte-parity
    claim is deliberately NOT made here."""
    from repro.core.ralmspec import RaLMSeq
    from repro.serving.fleet import FleetServer
    docs, enc, dkb, prompts, seng, beng = serve_stack
    retr_seq = ExactDenseRetriever(dkb, backend="int8-sharded", mesh_shards=4)
    want = [RaLMSeq(seng, retr_seq, _rcfg(), enc).serve(p).tokens
            for p in prompts]
    retr = ExactDenseRetriever(dkb, backend="int8-sharded", mesh_shards=4)
    assert retr.backend.n_shards == 4 and retr.backend.exact is False
    with FleetServer(beng, retr, _rcfg(), enc,
                     async_rounds=async_rounds) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == want, \
        "int8-sharded fleet diverged from RaLMSeq on the same backend"
    assert retr.backend.calls == fr.kb_calls == fr.rounds + 1


def test_int8_adr_continuous_serve_self_consistency(four_devices, serve_stack):
    """Continuous batching over the int8-sharded ADR probe: self-consistent
    with RaLMSeq on the same backend under churn, one collective per KB
    call."""
    from repro.core.ralmspec import RaLMSeq
    from repro.serving.continuous import ContinuousFleetServer, as_requests
    from repro.serving.batched import BatchedServeEngine
    docs, enc, dkb, prompts, seng, beng = serve_stack
    want = [RaLMSeq(seng, _adr_retr(dkb, backend="int8-sharded"), _rcfg(),
                    enc).serve(p).tokens for p in prompts]
    retr = _adr_retr(dkb, backend="int8-sharded")
    eng2 = BatchedServeEngine(beng.model, beng.params, 2, cache_window=256)
    server = ContinuousFleetServer(eng2, retr, _rcfg(), enc)
    cr = server.serve(as_requests(prompts, [0.0, 0.0, 1.0]))
    assert [r.tokens for r in cr.results] == want, \
        "int8-sharded ADR continuous fleet diverged from same-backend RaLMSeq"
    assert retr.backend.calls == retr.stats.calls


def test_serve_rejects_unsupported_backend_combo():
    """build_stack enforces the same support table the CLI validates against:
    SR alone rejects non-numpy backends — and the rejection NAMES the valid
    backends for the chosen retriever, not just the bad combo."""
    from repro.launch.serve import BACKEND_SUPPORT, build_stack
    from repro.retrieval.backends import BACKENDS
    assert BACKEND_SUPPORT["sr"] == ("numpy",)
    assert tuple(BACKEND_SUPPORT["edr"]) == tuple(BACKEND_SUPPORT["adr"]) \
        == BACKENDS
    for bad in ("sharded", "int8", "int8-sharded"):
        with pytest.raises(ValueError, match="does not support") as ei:
            build_stack("sr", n_docs=50, backend=bad)
        assert "supported: numpy" in str(ei.value), \
            "rejection must list the valid backends for the retriever"


def test_serve_cli_rejection_lists_supported_backends():
    """The CLI path of the same satellite: `--retriever sr
    --retriever-backend int8` exits 2 with a message naming the supported
    set, before any stack is built."""
    import os
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--retriever", "sr",
         "--retriever-backend", "int8"],
        cwd=root, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 2, out.stderr[-1500:]
    assert "does not support" in out.stderr
    assert "supported: numpy" in out.stderr
    assert "Traceback" not in out.stderr


def test_capability_table_workload_dimension():
    """The capability table's workload axis: KNN-LM has no SR cell (a BM25
    SparseKB carries no per-entry next-token values), every rejection flows
    through validate_stack's single error path naming the valid set, and
    every listed cell validates under every scheduler."""
    from repro.launch.serve import CAPABILITIES, SCHEDULERS, validate_stack
    assert ("knnlm", "sr") not in CAPABILITIES
    with pytest.raises(ValueError, match="does not support retriever") as ei:
        validate_stack("knnlm", "sr")
    assert "edr" in str(ei.value) and "adr" in str(ei.value)
    with pytest.raises(ValueError, match="unknown workload"):
        validate_stack("bogus", "edr")
    with pytest.raises(ValueError, match="unknown scheduler"):
        validate_stack("ralm", "edr", scheduler="bogus")
    for (w, r), backends in CAPABILITIES.items():
        for b in backends:
            for s in SCHEDULERS:
                validate_stack(w, r, b, s)


def test_serve_cli_rejects_knnlm_sparse_retriever():
    """CLI path of the workload axis: `--workload knnlm --retriever sr`
    exits 2 naming the retrievers KNN-LM does support, before any stack is
    built."""
    import os
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--workload", "knnlm",
         "--retriever", "sr"],
        cwd=root, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 2, out.stderr[-1500:]
    assert "does not support retriever" in out.stderr
    assert "edr" in out.stderr and "adr" in out.stderr
    assert "Traceback" not in out.stderr
