"""Output preservation under batched multi-request serving — the paper's central
claim extended to the fleet path:

  (a) BatchedServeEngine decodes token-for-token identically to the
      single-request ServeEngine (same prompts, same doc schedule),
  (b) fleet-served RaLMSpec outputs are byte-identical to per-request RaLMSeq
      outputs for EDR/ADR/SR at concurrency >= 2, and
  (c) mis-speculation in one fleet slot never perturbs sibling slots.

The claim is keyed on each backend's ``exact`` capability bit: EXACT backends
(numpy/kernel/sharded) are held to the cross-backend baseline — fleet through
backend X == RaLMSeq through numpy; INEXACT int8 backends are held to
self-consistency — fleet through X == RaLMSeq through the SAME X (the
speculate+verify loop needs one deterministic scan, not an exact one; the
recall-vs-fp32 contract lives in tests/test_quantized.py).

Engines are module-scoped (start() resets them) so the jit caches are shared
across tests — the fast tier pays each prefill shape once.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.knnlm import KNNLMSeq
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 3)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 3, cache_window=256)
    return model, params, docs, enc, dkb, skb, prompts, seng, beng


RCFG = RaLMConfig(max_new_tokens=20, speculation_stride=3)


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


# ---------------------------------------------------------------------------------
# (a) engine level: batched decode == single decode, token for token
# ---------------------------------------------------------------------------------
def test_batched_engine_matches_single(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    cases = [([5, 6, 7, 8], (1, 2, 3)), ([20, 21, 22], (4, 4)),
             ([7, 9, 30, 31, 12], ())]
    ks = [5, 3, 7]
    for b, (p, d) in enumerate(cases):
        beng.start(b, p, doc=d)
    slots = list(range(len(cases)))
    batched = beng.gen(slots, ks)
    for b in slots:
        beng.set_doc(b, (9, 10, 11))     # doc swap (re-prefill) mid-stream
    follow = beng.gen(slots, [4, 4, 4])
    for b, (p, d) in enumerate(cases):
        seng.start(p, doc=d)
        assert seng.gen(ks[b]) == batched[b], f"slot {b} diverged"
        seng.set_doc((9, 10, 11))
        assert seng.gen(4) == follow[b], f"slot {b} diverged after doc swap"


def test_batched_engine_eos_and_budget_exits(stack):
    """Slots leaving a lockstep gen early (budget) must freeze exactly at their
    own last step while siblings keep decoding."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    beng.start(0, [5, 6, 7, 8])
    beng.start(1, [9, 10, 11])
    a, b = beng.gen([0, 1], [2, 8])     # slot 0 exits 6 steps early
    c, = beng.gen([0], [3])             # slot 0 must resume from its own state
    seng.start([5, 6, 7, 8])
    assert seng.gen(2) == a and seng.gen(3) == c
    seng.start([9, 10, 11])
    assert seng.gen(8) == b


# ---------------------------------------------------------------------------------
# (b) fleet level: fleet RaLMSpec == per-request RaLMSeq, every retriever
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
def test_fleet_output_preservation(stack, retr_name):
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = _retriever(retr_name, dkb, skb)
    seq_tokens = [RaLMSeq(seng, retr, RCFG, enc).serve(p).tokens
                  for p in prompts]
    fr = FleetServer(beng, retr, RCFG, enc).serve(prompts)
    for i, r in enumerate(fr.results):
        assert r.tokens == seq_tokens[i], f"{retr_name}: slot {i} diverged"
        assert len(r.tokens) == RCFG.max_new_tokens
    # cross-request batched verification: ONE KB call per round (+ the initial
    # prefetch call), regardless of concurrency
    assert fr.kb_calls == fr.rounds + 1


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded", "int8",
                                     "int8-kernel", "int8-sharded"])
def test_fleet_preservation_matrix_keyed_on_exact_bit(stack, backend):
    """One matrix, two contracts, selected by the backend's `exact` bit:
    exact backends byte-match the numpy-backend RaLMSeq baseline (swapping
    the execution strategy may not perturb a served token); inexact int8
    backends byte-match RaLMSeq run through the SAME backend object
    (self-consistency), and either way the fleet still merges to one KB call
    per round. (Sharded backends collapse to a single shard on the 1-device
    CI leg — the program shape, not the shard count, is what preservation
    keys on.)"""
    from repro.retrieval.backends import BACKENDS
    assert backend in BACKENDS
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb, backend=backend)
    base_retr = ExactDenseRetriever(dkb) if retr.backend.exact else retr
    assert retr.backend.exact is (not backend.startswith("int8"))
    seq_tokens = [RaLMSeq(seng, base_retr, RCFG, enc).serve(p).tokens
                  for p in prompts]
    fr = FleetServer(beng, retr, RCFG, enc).serve(prompts)
    contract = "parity-vs-numpy" if retr.backend.exact else "self-consistency"
    for i, r in enumerate(fr.results):
        assert r.tokens == seq_tokens[i], \
            f"{backend}: slot {i} broke {contract}"
    assert fr.kb_calls == fr.rounds + 1
    if backend.endswith("sharded"):
        # one collective per KB call, fp32 and int8 meshes alike — note the
        # baseline RaLMSeq calls above also ride retr.backend when inexact
        assert retr.backend.calls == retr.stats.calls


def test_fleet_variants_preserve_outputs(stack):
    """Prefetching / OS3 must not change fleet outputs (paper Table 1, batched)."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    for variant in ("p", "s"):
        rcfg = dataclasses.replace(
            RCFG, prefetch_top_k=20 if "p" in variant else 1,
            use_os3="s" in variant)
        seq_tokens = [RaLMSeq(seng, retr, rcfg, enc).serve(p).tokens
                      for p in prompts[:2]]
        fr = FleetServer(beng, retr, rcfg, enc).serve(prompts[:2])
        for i, r in enumerate(fr.results):
            assert r.tokens == seq_tokens[i], f"variant {variant}: slot {i}"


def test_single_request_async_carry_fast_guard(stack):
    """Fast-tier guard for the single-request async-verification carry path
    (the full variant sweep lives in the slow tier — without this, a carry
    regression would only surface under `-m slow`; the fleet's multi-step
    carry has its own fast guards in tests/test_async_fleet.py).
    Budget 17 ends mid-stride, exercising the carry-at-boundary case."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, async_verification=True, max_new_tokens=17)
    r1 = RaLMSeq(seng, retr, rcfg, enc).serve(prompts[0])
    r2 = RaLMSpec(seng, retr, rcfg, enc).serve(prompts[0])
    assert r1.tokens == r2.tokens


def test_fleet_matches_single_request_spec(stack):
    """The fleet at concurrency 1 is the single-request algorithm: same tokens
    as RaLMSpec."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    spec = RaLMSpec(seng, retr, RCFG, enc).serve(prompts[0])
    fr = FleetServer(beng, retr, RCFG, enc).serve(prompts[:1])
    assert fr.results[0].tokens == spec.tokens


# ---------------------------------------------------------------------------------
# (b') KNN-LM workload through the same fleet paths: per-token retrieval +
# token-match verification (KNNLMWorkload behind the Workload seam) must equal
# per-request KNNLMSeq on every serving path and datastore backend, and the
# merged-KB-call invariant must survive the workload swap.
# ---------------------------------------------------------------------------------
KNN_RCFG = RaLMConfig(knnlm=True, knn_k=8, max_new_tokens=16,
                      speculation_stride=3)


@pytest.fixture(scope="module")
def knn(stack):
    """Small KNN-LM datastore over the module corpus's token stream, plus a
    lazy per-backend KNNLMSeq baseline cache (exact backends are byte-parity,
    but self-computing per backend keeps the contract honest)."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    vocab = reduced(get_config("ralm-gpt2-medium")).vocab_size
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs[:300]])
    kenc = ContextEncoder(vocab, d=32, window=16)
    ds = build_knn_datastore(stream, kenc, context=16, limit=6000)
    kprompts = [stream[i * 97:i * 97 + 48].tolist() for i in range(3)]
    baselines = {}

    def seq_tokens(backend):
        if backend not in baselines:
            retr = ExactDenseRetriever(ds, backend=backend)
            baselines[backend] = [
                KNNLMSeq(seng, retr, KNN_RCFG, kenc).serve(p).tokens
                for p in kprompts]
        return baselines[backend]

    return kenc, ds, kprompts, seq_tokens


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("mode", ["fleet", "continuous", "async"])
def test_knnlm_serving_preservation(stack, knn, mode, backend):
    """KNN-LM fleet serving == per-request KNNLMSeq, token for token, on all
    three serving paths x exact datastore backends — plus the one merged KB
    call per round invariant (and for sharded, one collective per KB call)."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    kenc, ds, kprompts, seq_tokens = knn
    base = seq_tokens(backend)
    retr = ExactDenseRetriever(ds, backend=backend)
    rcfg = KNN_RCFG
    if mode == "async":
        # forced-open gate + full-stride overlap: the two-stage pipeline
        # engages deterministically even on this cheap test datastore
        rcfg = dataclasses.replace(rcfg, async_verification=True,
                                   async_gate_ratio=0.0, async_min_overlap=4)
    cls = ContinuousFleetServer if mode == "continuous" else FleetServer
    with cls(beng, retr, rcfg, kenc) as srv:
        fr = (srv.serve(as_requests(kprompts)) if mode == "continuous"
              else srv.serve(kprompts))
    for i, r in enumerate(fr.results):
        assert r.tokens == base[i], f"{mode}/{backend}: slot {i} diverged"
        assert len(r.tokens) == KNN_RCFG.max_new_tokens
    if mode == "continuous":
        assert fr.kb_calls == fr.rounds + fr.seed_calls
    else:
        assert fr.kb_calls == fr.rounds + 1
    if backend == "sharded":
        # one collective per merged KB call, KNN-LM workload included
        assert retr.backend.calls == retr.stats.calls


# ---------------------------------------------------------------------------------
# (c) rollback isolation: one slot's mis-speculation leaves siblings untouched
# ---------------------------------------------------------------------------------
def test_fleet_rollback_under_mis_speculation(stack):
    """Force heavy mis-speculation (capacity-1 cache) — every slot rolls back
    repeatedly, outputs must still equal the sequential baseline."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, cache_capacity=1)
    seq_tokens = [RaLMSeq(seng, retr, rcfg, enc).serve(p).tokens
                  for p in prompts]
    fr = FleetServer(beng, retr, rcfg, enc).serve(prompts)
    assert sum(r.mismatches for r in fr.results) > 0, \
        "capacity-1 cache should force mis-speculation"
    for i, r in enumerate(fr.results):
        assert r.tokens == seq_tokens[i], f"slot {i} perturbed by rollback"


def test_rollback_in_one_slot_does_not_perturb_siblings(stack):
    """Engine-level regression: snapshot/rollback on slot 0 while slot 1 holds
    state — slot 1's tokens and continuation must be unaffected."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng = stack
    beng.start(0, [5, 6, 7, 8])
    beng.start(1, [40, 41, 42, 43])
    beng.gen([0, 1], [3, 3])
    sibling_before = list(beng.tokens[1])
    snap = beng.snapshot(0)
    beng.set_doc(0, (2, 3, 4))          # slot 0 speculates: doc swap + stride
    beng.gen([0], [4])
    beng.restore(0, snap)               # mis-speculation: roll slot 0 back
    assert beng.tokens[1] == sibling_before
    cont = beng.gen([0, 1], [3, 3])     # both resume; slot 1 as if undisturbed
    seng.start([40, 41, 42, 43])
    seng.gen(3)
    assert seng.gen(3) == cont[1]
    seng.start([5, 6, 7, 8])
    seng.gen(3)
    assert seng.gen(3) == cont[0]
