"""Chaos suite for the fault-tolerance shell (repro.retrieval.faults +
_ServerBase._retrieve_guarded + the fleet degradation paths):

  (a) the injector itself: a seeded schedule is a pure function of
      (seed, call index) — two runs inject identical faults — and the
      --inject-faults DSL parses/round-trips with one-line errors,
  (b) PRESERVATION under transient faults: fleet / continuous / async
      rounds x EDR / ADR / SR stay byte-identical to per-request RaLMSeq
      while a seeded schedule of injected errors is retried away (KB search
      is deterministic, so a retried call returns byte-identical rows),
  (c) the per-call deadline: latency spikes past ``retrieval_timeout_s``
      are discarded and retried, counted as timeouts, outputs untouched,
  (d) worker-crash recovery: an async verification call that dies on the
      worker thread falls back to a synchronous round (overlap invalidated
      exactly as on rollback) instead of hanging or poisoning close(),
  (e) graceful degradation: a merged call that fails for good degrades the
      round to speculation-only (requests marked 'degraded', exempt from
      byte-parity) — or re-raises when ``degrade_on_failure`` is off,
  (f) overload shedding: the bounded admission queue / queueing deadline
      retire requests with status='shed' while admitted requests still
      serve byte-identical tokens,
  (g) hygiene: no thread leak after an exception mid-serve (the context
      manager releases the verification worker), and the serve CLI maps
      malformed traces / misplaced fault flags to one-line argparse errors.

Engines are module-scoped (serve() resets them) so jit caches are shared.
"""
import dataclasses
import sys
import threading

import jax
import pytest

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSeq
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.faults import (FaultInjector, FaultSpec, FaultyBackend,
                                    RetrievalFailed, TransientRetrievalError,
                                    inject_faults, parse_fault_spec)
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


@pytest.fixture(scope="module")
def stack():
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs = synthetic_corpus(1500, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=32)
    dkb = DenseKB.build(docs, enc)
    skb = SparseKB.build(docs)
    prompts = [(q * 10)[:32] for q in make_queries(docs, 3)]
    seng = ServeEngine(model, params, cache_window=256)
    beng = BatchedServeEngine(model, params, 3, cache_window=256)
    beng2 = BatchedServeEngine(model, params, 2, cache_window=256)
    return model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2


RCFG = RaLMConfig(max_new_tokens=20, speculation_stride=3,
                  async_gate_ratio=0.0, async_min_overlap=16)
BUDGETS = [20, 8, 14]

# the canonical transient outage: at most 6 injected faults total
# (max_faults), so with retry_max=6 (7 attempts per call) EVERY call is
# guaranteed to eventually succeed — the schedule is provably recoverable,
# whatever calls the seeded draw lands its faults on
CHAOS = FaultSpec(seed=7, p_error=0.4, p_spike=0.3, spike_s=0.002,
                  max_faults=6)
CHAOS_RCFG = dataclasses.replace(RCFG, retry_max=6)


def _retriever(name, dkb, skb):
    return {"edr": lambda: ExactDenseRetriever(dkb),
            "adr": lambda: IVFRetriever(dkb, n_clusters=16, nprobe=2),
            "sr": lambda: BM25Retriever(skb)}[name]()


def _seq_tokens(seng, retr, enc, rcfg, prompt, budget=None):
    one = rcfg if budget is None else dataclasses.replace(
        rcfg, max_new_tokens=budget)
    return RaLMSeq(seng, retr, one, enc).serve(prompt).tokens


# ---------------------------------------------------------------------------------
# (a) the injector: seeded determinism + DSL parsing
# ---------------------------------------------------------------------------------
def _schedule(spec, n):
    inj = FaultInjector(spec)
    for _ in range(n):
        try:
            inj.fire()
        except TransientRetrievalError:
            pass
    return inj


def test_same_seed_same_schedule():
    spec = FaultSpec(seed=3, p_error=0.3, p_spike=0.3, spike_s=0.0)
    a, b = _schedule(spec, 80), _schedule(spec, 80)
    assert a.log == b.log, "same seed must inject the identical schedule"
    kinds = {k for _, k in a.log}
    assert kinds == {"ok", "error", "spike"}, \
        "80 draws at p=0.3 should exercise every decision kind"
    assert (a.calls, a.errors, a.spikes) == (b.calls, b.errors, b.spikes)


def test_schedule_independent_of_rates():
    """The uniforms are drawn unconditionally, so a call that errors under
    (p_error=0.3) errors at the same index under (p_error=0.3, p_spike=0.9)
    — the error draw is not perturbed by the spike rate."""
    lo = _schedule(FaultSpec(seed=11, p_error=0.3), 60)
    hi = _schedule(FaultSpec(seed=11, p_error=0.3, p_spike=1.0), 60)
    err_lo = {i for i, k in lo.log if k == "error"}
    err_hi = {i for i, k in hi.log if k == "error"}
    assert err_lo == err_hi


def test_explicit_call_indices_and_cap():
    inj = _schedule(FaultSpec(error_calls=(2, 5), spike_calls=(3,)), 8)
    assert inj.log == [(0, "ok"), (1, "ok"), (2, "error"), (3, "spike"),
                       (4, "ok"), (5, "error"), (6, "ok"), (7, "ok")]
    capped = _schedule(FaultSpec(p_error=1.0, max_faults=3), 10)
    assert capped.errors == 3
    assert [k for _, k in capped.log] == ["error"] * 3 + ["ok"] * 7


def test_parse_fault_spec_roundtrip():
    spec = parse_fault_spec(
        "p_error=0.2, p_spike=0.1, spike_s=0.05, seed=9, "
        "error_calls=1;4;7, spike_calls=2, max_faults=5")
    assert spec == FaultSpec(seed=9, p_error=0.2, p_spike=0.1, spike_s=0.05,
                             error_calls=(1, 4, 7), spike_calls=(2,),
                             max_faults=5)
    assert parse_fault_spec("") == FaultSpec()


@pytest.mark.parametrize("bad", [
    "p_error",                  # no '='
    "nope=1",                   # unknown key
    "p_error=lots",             # unparsable value
    "p_error=1.5",              # probability out of range
    "spike_s=-1",               # negative spike
    "error_calls=1;x",          # unparsable call index
])
def test_parse_fault_spec_rejects(bad):
    with pytest.raises(ValueError) as ei:
        parse_fault_spec(bad)
    assert "\n" not in str(ei.value), "CLI wants a one-line message"


def test_faulty_backend_delegates(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    inner = retr.backend
    wrapped = FaultyBackend(inner, FaultSpec())  # no faults: pure passthrough
    assert wrapped.name == inner.name and wrapped.exact == inner.exact
    q = [enc.encode(prompts[0])]
    import numpy as np
    a = inner.search(np.asarray(q), 3)
    b = wrapped.search(np.asarray(q), 3)
    assert (a[0] == b[0]).all() and wrapped.injector.calls == 1


# ---------------------------------------------------------------------------------
# (b) preservation under transient faults, every scheduler x every retriever
# ---------------------------------------------------------------------------------
@pytest.mark.parametrize("retr_name", ["edr", "adr", "sr"])
@pytest.mark.parametrize("mode", ["fleet", "continuous", "async"])
def test_preservation_under_transient_faults(stack, mode, retr_name):
    """A seeded, provably-transient fault schedule (see CHAOS) on the merged
    verification call: retries return byte-identical rows, so every request
    must match per-request RaLMSeq on a CLEAN retriever — and the faults must
    actually have fired."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    clean = _retriever(retr_name, dkb, skb)
    faulty = _retriever(retr_name, dkb, skb)
    inj = inject_faults(faulty, CHAOS)

    if mode == "continuous":
        seq = [_seq_tokens(seng, clean, enc, CHAOS_RCFG, p, mn)
               for p, mn in zip(prompts, BUDGETS)]
        with ContinuousFleetServer(beng2, faulty, CHAOS_RCFG, enc,
                                   async_rounds=False) as server:
            res = server.serve(as_requests(prompts, arrivals=[0, 0, 1e-4],
                                           max_new=BUDGETS))
    else:
        seq = [_seq_tokens(seng, clean, enc, CHAOS_RCFG, p) for p in prompts]
        with FleetServer(beng, faulty, CHAOS_RCFG, enc,
                         async_rounds=(mode == "async")) as server:
            res = server.serve(prompts)

    assert inj.injected > 0, "the chaos schedule never fired"
    assert res.kb_failures == 0, "max_faults < attempts: no call may fail"
    assert res.kb_errors > 0, "injected errors should surface as retries"
    for i, r in enumerate(res.results):
        assert r.status == "ok"
        assert r.tokens == seq[i], \
            f"{mode}/{retr_name}: request {i} diverged under injected faults"
    if mode == "async":
        assert sum(r.carry_steps + r.carry_invalidations
                   for r in res.results) > 0, "pipeline never overlapped"


# ---------------------------------------------------------------------------------
# (c) latency spikes vs the per-call deadline
# ---------------------------------------------------------------------------------
def test_timeout_discards_and_retries(stack):
    """Spikes on the first two KB scans push them past the deadline: both are
    discarded (counted as timeouts) and the retry — deterministic KB — keeps
    outputs byte-identical."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    clean = ExactDenseRetriever(dkb)
    faulty = ExactDenseRetriever(dkb)
    inject_faults(faulty, FaultSpec(spike_calls=(0, 1), spike_s=0.3))
    rcfg = dataclasses.replace(RCFG, retrieval_timeout_s=0.1, retry_max=3)
    seq = [_seq_tokens(seng, clean, enc, rcfg, p) for p in prompts]
    with FleetServer(beng, faulty, rcfg, enc, async_rounds=False) as fleet:
        fr = fleet.serve(prompts)
    assert fr.kb_timeouts == 2, "both spiked attempts should time out"
    assert fr.kb_failures == 0 and fr.kb_errors == 0
    for i, r in enumerate(fr.results):
        assert r.status == "ok" and r.tokens == seq[i]


# ---------------------------------------------------------------------------------
# (d) worker-crash recovery on the async pipeline
# ---------------------------------------------------------------------------------
def test_worker_crash_recovers_synchronously(stack):
    """retry_max=0 and an error forced on KB call 1 (the first merged
    verification call; call 0 is the seed): the call dies ON THE WORKER
    THREAD. The round must invalidate its overlapped stride, re-run the call
    synchronously (fresh budget, next injector index is clean), and keep
    every output byte-identical — and close() must not hang on the carcass."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    clean = ExactDenseRetriever(dkb)
    faulty = ExactDenseRetriever(dkb)
    inject_faults(faulty, FaultSpec(error_calls=(1,)))
    rcfg = dataclasses.replace(RCFG, retry_max=0)
    seq = [_seq_tokens(seng, clean, enc, rcfg, p) for p in prompts]
    with FleetServer(beng, faulty, rcfg, enc, async_rounds=True) as fleet:
        fr = fleet.serve(prompts)
    assert fr.worker_crashes == 1, "the in-flight call should have died"
    assert fr.kb_failures == 1, "retry_max=0: the worker call failed for good"
    assert fr.degraded_rounds == 0, "the sync fallback must have saved it"
    for i, r in enumerate(fr.results):
        assert r.status == "ok" and r.tokens == seq[i]


# ---------------------------------------------------------------------------------
# (e) graceful degradation when the KB is unreachable for good
# ---------------------------------------------------------------------------------
def test_degraded_rounds_keep_serving(stack):
    """p_error=1.0: every attempt of every call fails. The fleet must keep
    the streams alive — speculation-only rounds, requests marked 'degraded'
    (byte-parity exemption), seed failure absorbed — instead of dying."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    faulty = ExactDenseRetriever(dkb)
    inject_faults(faulty, FaultSpec(p_error=1.0))
    rcfg = dataclasses.replace(RCFG, retry_max=1)
    with FleetServer(beng, faulty, rcfg, enc, async_rounds=False) as fleet:
        fr = fleet.serve(prompts)
    assert fr.seed_failures == 1, "the seed call fails but is absorbed"
    assert fr.degraded_rounds > 0 and fr.kb_failures > 0
    assert fr.degraded_requests == len(prompts)
    for r in fr.results:
        assert r.status == "degraded" and not r.ok
        assert r.tokens, "a degraded stream must still serve tokens"


def test_degrade_off_reraises(stack):
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    faulty = ExactDenseRetriever(dkb)
    inject_faults(faulty, FaultSpec(p_error=1.0))
    rcfg = dataclasses.replace(RCFG, retry_max=1, degrade_on_failure=False)
    with FleetServer(beng, faulty, rcfg, enc, async_rounds=False) as fleet:
        with pytest.raises(RetrievalFailed):
            fleet.serve(prompts)


# ---------------------------------------------------------------------------------
# (f) overload shedding on the continuous scheduler
# ---------------------------------------------------------------------------------
def test_shed_under_overload(stack):
    """6 simultaneous arrivals on 2 slots with a depth-1 queue and a 0.5s
    queueing deadline: the fleet admits what it can serve, sheds the rest
    (status='shed', no tokens, OUT of the latency distribution), and the
    admitted requests still serve byte-identical tokens."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    rcfg = dataclasses.replace(RCFG, max_queue_depth=1, queue_deadline_s=0.5)
    six = [prompts[i % len(prompts)] for i in range(6)]
    seq = {tuple(p): _seq_tokens(seng, retr, enc, rcfg, p) for p in prompts}
    with ContinuousFleetServer(beng2, retr, rcfg, enc,
                               async_rounds=False) as server:
        out = server.serve(as_requests(six))
    assert out.shed >= 3, "a depth-1 queue on 2 slots must shed most of 6"
    served = [r for r in out.results if r.status == "ok"]
    assert len(served) + out.shed == 6
    assert len(out.latencies) == len(served), \
        "shed requests must stay out of the latency distribution"
    for rid, r in enumerate(out.results):   # results are in request order
        if r.status == "shed":
            assert r.tokens == [] and not r.ok
        else:
            assert r.tokens == seq[tuple(six[rid])], \
                f"request {rid} diverged while neighbors were shed"


# ---------------------------------------------------------------------------------
# (g) hygiene: thread leaks and CLI error mapping
# ---------------------------------------------------------------------------------
def test_no_thread_leak_after_mid_serve_crash(stack, monkeypatch):
    """Crash the engine mid-round AFTER the verification worker has spawned
    (call 5 lands in the overlapped stride of round 1, while the merged call
    is in flight): the context manager must join the worker and release it —
    thread count returns to the pre-server baseline, close() stays
    idempotent."""
    model, params, docs, enc, dkb, skb, prompts, seng, beng, beng2 = stack
    retr = ExactDenseRetriever(dkb)
    real_gen, calls = beng.gen, [0]

    def crashing_gen(*a, **kw):
        calls[0] += 1
        if calls[0] >= 5:
            raise RuntimeError("injected engine crash")
        return real_gen(*a, **kw)

    monkeypatch.setattr(beng, "gen", crashing_gen)
    before = threading.active_count()
    fleet = FleetServer(beng, retr, RCFG, enc, async_rounds=True)
    with fleet:
        with pytest.raises(RuntimeError, match="injected engine crash"):
            fleet.serve(prompts)
    assert threading.active_count() <= before, \
        "the verification worker thread leaked past close()"
    fleet.close()   # idempotent after __exit__


def _cli(monkeypatch, capsys, argv):
    from repro.launch import serve as serve_mod
    monkeypatch.setattr(sys, "argv", ["serve"] + argv)
    with pytest.raises(SystemExit) as ei:
        serve_mod.main()
    assert ei.value.code == 2
    return capsys.readouterr().err


def test_cli_rejects_bad_arrival_trace(monkeypatch, capsys):
    err = _cli(monkeypatch, capsys,
               ["--scheduler", "continuous",
                "--arrival-trace", "@/no/such/trace.txt"])
    assert "--arrival-trace" in err and "cannot read" in err
    err = _cli(monkeypatch, capsys,
               ["--scheduler", "continuous", "--arrival-trace", "0,zap,2"])
    assert "malformed arrival time" in err


def test_cli_rejects_misplaced_fault_flags(monkeypatch, capsys):
    # malformed spec
    err = _cli(monkeypatch, capsys, ["--mode", "spec", "--concurrency", "2",
                                     "--inject-faults", "p_error=lots"])
    assert "--inject-faults" in err
    # the RaLMSeq baseline has no fault-tolerance shell
    err = _cli(monkeypatch, capsys, ["--mode", "both", "--concurrency", "2",
                                     "--inject-faults", "p_error=0.1"])
    assert "--mode spec" in err
    # the single-request path has no shell either
    err = _cli(monkeypatch, capsys, ["--mode", "spec",
                                     "--inject-faults", "p_error=0.1"])
    assert "fleet scheduler" in err


def test_make_arrivals_trace_file(tmp_path):
    from repro.launch.serve import make_arrivals
    f = tmp_path / "trace.txt"
    f.write_text("0.0\n0.5  # a comment\n\n1.25\n")
    assert make_arrivals(5, 0.0, f"@{f}") == [0.0, 0.5, 1.25, 0.0, 0.5]
    with pytest.raises(ValueError, match="empty"):
        make_arrivals(3, 0.0, " , ,")
    with pytest.raises(ValueError, match=">= 0"):
        make_arrivals(3, 0.0, "0,-1")
