"""Decode path == full forward for every architecture (prefill handoff, ring cache,
recurrent state snapshots)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models.model import build_model

T = 12


def _extra(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (2, cfg.encoder_frames,
                                                   cfg.d_model)) * 0.1}
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(key, (2, cfg.vision_patches,
                                                   cfg.d_model)) * 0.1}
    return None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    extra = _extra(cfg, key)

    last, state, pos = model.prefill(params, toks[:, :T - 1], extra=extra)
    dec, _ = model.decode_step(params, state, toks[:, T - 1], pos)

    if cfg.moe is not None:
        # MoE: forward() uses capacity dispatch (train path) which is batch-
        # composition dependent; compare against the exact serving path instead.
        ref, _, _ = model.prefill(params, toks, extra=extra)
    else:
        logits, _ = model.forward(params, toks, extra=extra)
        ref = logits[:, -1]
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-350m", "jamba-v0.1-52b"])
def test_multi_step_greedy_decode_consistency(arch):
    """Greedy continuation via decode equals re-prefilled greedy continuation."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)

    last, state, pos = model.prefill(params, prompt)
    toks = []
    logits = last
    for _ in range(6):
        t = int(jnp.argmax(logits[0]))
        toks.append(t)
        logits, state = model.decode_step(params, state,
                                          jnp.asarray([t], jnp.int32), pos)
        pos = pos + 1

    # reference: prefill(prompt + emitted prefix) then argmax
    ctx = list(np.asarray(prompt[0]))
    for i, t in enumerate(toks[:-1]):
        ref_last, _, _ = model.prefill(
            params, jnp.asarray([ctx + toks[:i + 1]], jnp.int32))
        assert int(jnp.argmax(ref_last[0])) == toks[i + 1], f"step {i} diverged"
