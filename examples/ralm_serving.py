"""RaLM serving example across all three retriever types with the full PSA feature
set — the paper's Figure 4 in miniature.

    PYTHONPATH=src python examples/ralm_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import RaLMConfig
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.launch.serve import build_stack, variant_config
from repro.serving.engine import ServeEngine
from repro.training.data import make_queries


def main():
    rcfg = variant_config("psa", RaLMConfig(max_new_tokens=32))
    for retriever in ("edr", "adr", "sr"):
        cfg, model, params, docs, enc, retr = build_stack(retriever, n_docs=8000)
        eng = ServeEngine(model, params, cache_window=512)
        prompt = (make_queries(docs, 1, seed=4)[0] * 12)[:48]
        base = RaLMSeq(eng, retr, rcfg, enc).serve(prompt)
        spec = RaLMSpec(eng, retr, rcfg, enc).serve(prompt)
        assert base.tokens == spec.tokens
        print(f"{retriever.upper():3s}: baseline {base.kb_calls:2d} KB calls -> "
              f"ralmspec {spec.kb_calls:2d} calls "
              f"(rounds={spec.rounds}, rollbacks={spec.mismatches}, "
              f"outputs identical)")


if __name__ == "__main__":
    main()
