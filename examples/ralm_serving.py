"""RaLM serving example across all three retriever types with the full PSA feature
set — the paper's Figure 4 in miniature.

    PYTHONPATH=src python examples/ralm_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig
from repro.launch.serve import build_stack, make_server, variant_config
from repro.training.data import make_queries


def main():
    rcfg = variant_config("psa", RaLMConfig(max_new_tokens=32))
    for retriever in ("edr", "adr", "sr"):
        stack = build_stack(retriever, n_docs=8000, rcfg=rcfg)
        prompt = (make_queries(stack.docs, 1, seed=4)[0] * 12)[:48]
        base = make_server(stack, scheduler="seq").serve(prompt)
        spec = make_server(stack, scheduler="single").serve(prompt)
        assert base.tokens == spec.tokens
        print(f"{retriever.upper():3s}: baseline {base.kb_calls:2d} KB calls -> "
              f"ralmspec {spec.kb_calls:2d} calls "
              f"(rounds={spec.rounds}, rollbacks={spec.mismatches}, "
              f"outputs identical)")


if __name__ == "__main__":
    main()
