"""End-to-end training driver example: train the paper's KNN-LM base model class
(~100M-scale reduced here for CPU; pass --full on real hardware) for a few hundred
steps and checkpoint it.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full 247M config (use on real hardware)")
    args = ap.parse_args()
    argv = ["--arch", "knnlm-247m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_ckpt",
            "--ckpt-every", str(max(args.steps // 2, 1))]
    if not args.full:
        argv.append("--reduced")
    sys.argv = ["train"] + argv
    train_mod.main()


if __name__ == "__main__":
    main()
