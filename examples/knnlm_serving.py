"""KNN-LM speculative serving example (paper §5.3): per-token retrieval with
spatial-prefetch caching and token-match verification.

    PYTHONPATH=src python examples/knnlm_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.knnlm import KNNLMSeq, KNNLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import build_knn_datastore
from repro.retrieval.retrievers import ExactDenseRetriever
from repro.serving.engine import ServeEngine
from repro.training.data import synthetic_corpus


def main():
    cfg = reduced(get_config("knnlm-247m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    docs = synthetic_corpus(800, cfg.vocab_size)
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs])
    enc = ContextEncoder(cfg.vocab_size, d=64, window=16)
    ds = build_knn_datastore(stream, enc, context=16, limit=20_000)
    retriever = ExactDenseRetriever(ds)
    print(f"datastore: {ds.size} (context -> next-token) entries")

    rcfg = RaLMConfig(knnlm=True, knn_k=8, max_new_tokens=32,
                      speculation_stride=4)
    eng = ServeEngine(model, params, cache_window=256)
    prompt = stream[:48].tolist()
    base = KNNLMSeq(eng, retriever, rcfg, enc).serve(prompt)
    spec = KNNLMSpec(eng, retriever, rcfg, enc).serve(prompt)
    assert base.tokens == spec.tokens
    print(f"baseline : {base.kb_calls} retrievals (one per token)")
    print(f"ralmspec : {spec.kb_calls} batched retrievals, "
          f"{spec.mismatches} rollbacks, outputs identical")


if __name__ == "__main__":
    main()
