"""KNN-LM speculative serving example (paper §5.3): per-token retrieval with
spatial-prefetch caching and token-match verification — single-request and
through the fleet (same merged-KB round loop as RaLM, different workload).

    PYTHONPATH=src python examples/knnlm_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig
from repro.launch.serve import build_stack, make_server


def main():
    rcfg = RaLMConfig(knnlm=True, knn_k=8, max_new_tokens=32,
                      speculation_stride=4)
    stack = build_stack("edr", workload="knnlm", arch="knnlm-247m",
                        n_docs=800, d_model=128, rcfg=rcfg, knn_entries=20_000)
    print(f"datastore: {stack.retriever.kb.size} (context -> next-token) "
          "entries")

    # prompts are prefixes of the datastore's own token stream — the regime
    # where neighbour retrieval carries signal
    prompts = [stack.stream[i * 97:i * 97 + 48].tolist() for i in range(3)]
    seq = make_server(stack, scheduler="seq")
    base = [seq.serve(p) for p in prompts]
    spec = make_server(stack, scheduler="single").serve(prompts[0])
    assert base[0].tokens == spec.tokens
    print(f"baseline : {base[0].kb_calls} retrievals (one per token)")
    print(f"ralmspec : {spec.kb_calls} batched retrievals, "
          f"{spec.mismatches} rollbacks, outputs identical (token-match)")

    # the fleet: every slot's verification queries merge into ONE batched KB
    # call per round; per-slot token streams still match the baseline
    with make_server(stack, scheduler="fixed", n_slots=3) as fleet:
        fr = fleet.serve(prompts)
    assert [r.tokens for r in fr.results] == [b.tokens for b in base]
    assert fr.kb_calls == fr.rounds + 1      # 1 seed + 1 merged call per round
    print(f"fleet x3 : {fr.kb_calls} merged KB calls over {fr.rounds} rounds "
          f"for 3 requests, outputs identical (token-match)")


if __name__ == "__main__":
    main()
