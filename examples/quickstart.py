"""Quickstart: build the whole stack at toy scale and watch RaLMSpec preserve the
baseline's output while cutting knowledge-base calls.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB
from repro.retrieval.retrievers import ExactDenseRetriever
from repro.serving.engine import ServeEngine
from repro.training.data import make_queries, synthetic_corpus


def main():
    # 1. a host LM (reduced GPT-2-class decoder) ------------------------------
    cfg = reduced(get_config("ralm-gpt2-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. a knowledge base + exact dense retriever ------------------------------
    docs = synthetic_corpus(5000, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=64)
    retriever = ExactDenseRetriever(DenseKB.build(docs, enc))

    # 3. serve one request with the baseline and with RaLMSpec -----------------
    rcfg = RaLMConfig(max_new_tokens=32, speculation_stride=3,
                      prefetch_top_k=20)
    engine = ServeEngine(model, params, cache_window=512)
    prompt = (make_queries(docs, 1)[0] * 12)[:48]

    base = RaLMSeq(engine, retriever, rcfg, enc).serve(prompt)
    spec = RaLMSpec(engine, retriever, rcfg, enc).serve(prompt)

    print(f"baseline : {base.kb_calls} KB calls, {base.wall_time:.2f}s")
    print(f"ralmspec : {spec.kb_calls} KB calls, {spec.wall_time:.2f}s "
          f"({spec.rounds} verification rounds, {spec.mismatches} rollbacks)")
    print(f"outputs identical: {base.tokens == spec.tokens}")
    assert base.tokens == spec.tokens


if __name__ == "__main__":
    main()
