"""Data pipeline.

Two sources, same iterator protocol:
  * ``SyntheticLM``      — deterministic pseudo-random token stream with planted
                           n-gram structure (so loss actually falls during the
                           end-to-end example run).
  * ``CorpusLM``         — tokenized document corpus (the same synthetic Wikipedia-like
                           corpus the retrieval stack indexes), packed into fixed-length
                           training sequences.

Both yield {"tokens": (B, S) int32, "labels": (B, S) int32} host-side numpy; the
launcher moves them onto the mesh with jax.device_put + NamedSharding.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


def _rng(seed) -> np.random.Generator:
    if isinstance(seed, str):
        seed = int(hashlib.sha1(seed.encode()).hexdigest()[:8], 16)
    return np.random.default_rng(seed)


@dataclass
class SyntheticLM:
    """Markov-ish synthetic stream: each vocab id prefers a successor, so a model can
    reduce loss well below uniform. Deterministic per (seed, step)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        g = _rng(self.seed)
        self.successor = g.integers(0, self.vocab_size, size=self.vocab_size)

    def batch(self, step: int) -> dict:
        g = _rng(self.seed * 1_000_003 + step)
        B, S = self.batch_size, self.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = g.integers(0, self.vocab_size, size=B)
        noise = g.random((B, S)) < 0.25
        rand = g.integers(0, self.vocab_size, size=(B, S))
        for t in range(1, S):
            nxt = self.successor[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class CorpusLM:
    """Pack tokenized documents into contiguous training sequences."""

    def __init__(self, docs_tokens: list, seq_len: int, batch_size: int,
                 eos_id: int = 0, seed: int = 0):
        self.seq = seq_len
        self.bs = batch_size
        stream = []
        for d in docs_tokens:
            stream.extend(d)
            stream.append(eos_id)
        self.stream = np.asarray(stream, np.int32)
        self.g = _rng(seed)

    def batch(self, step: int) -> dict:
        g = _rng(step)
        n = len(self.stream) - self.seq - 1
        starts = g.integers(0, max(n, 1), size=self.bs)
        toks = np.stack([self.stream[s:s + self.seq] for s in starts])
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


# --------------------------------------------------------------------------------------
# synthetic retrieval corpus (shared with the retrieval stack + serving benchmarks)
# --------------------------------------------------------------------------------------
_TOPIC_WORDS = 64     # words per topic cluster
_WORDS_PER_DOC = 48


def synthetic_corpus(n_docs: int, vocab_size: int, *, n_topics: int = 32,
                     seed: int = 7) -> list:
    """Wikipedia-like synthetic corpus with topical clustering: documents in the same
    topic share a skewed word distribution, giving retrieval the temporal/spatial
    locality structure the paper's cache exploits. Consecutive doc ids within a topic
    are 'consecutive passages' (spatial locality for KNN-LM prefetch)."""
    g = _rng(seed)
    topic_vocab = [
        g.integers(2, vocab_size, size=_TOPIC_WORDS) for _ in range(n_topics)
    ]
    docs = []
    for i in range(n_docs):
        topic = (i * n_topics) // n_docs          # consecutive docs share topics
        tv = topic_vocab[topic]
        # 80% topical words, 20% background
        k = _WORDS_PER_DOC
        topical = tv[g.integers(0, len(tv), size=int(k * 0.8))]
        background = g.integers(2, vocab_size, size=k - len(topical))
        words = np.concatenate([topical, background])
        g.shuffle(words)
        docs.append(words.astype(np.int32).tolist())
    return docs


def make_queries(docs: list, n_queries: int, *, seed: int = 11) -> list:
    """Question-like queries: a few words sampled from a (random) target doc plus
    noise — mimics context-dependent queries drifting within a topic."""
    g = _rng(seed)
    qs = []
    for _ in range(n_queries):
        d = docs[g.integers(0, len(docs))]
        take = g.integers(3, 8)
        idx = g.integers(0, len(d), size=take)
        qs.append([d[i] for i in idx])
    return qs
