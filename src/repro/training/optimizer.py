"""AdamW + schedules, from scratch (no optax in this environment).

State is a pytree-of-pytrees mirroring the params, so it shards with the same
PartitionSpecs as the parameters (ZeRO-style: optimizer state inherits the param
sharding, which is already FSDP-sharded over 'data').
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / max(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return sched


def init_adamw(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    sched = cosine_schedule(cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    step = state.step + 1
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)
    lr = sched(step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
