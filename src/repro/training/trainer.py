"""Train-step factory: loss, grads, AdamW update, all pjit-shardable."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model, build_model
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Next-token cross entropy; labels < 0 are masked (e.g. image prefix)."""
    V = logits.shape[-1]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(model: Model, *, window: int = 0, remat: bool = False):
    cfg = model.cfg

    def loss_fn(params, batch):
        extra = {k: v for k, v in batch.items()
                 if k in ("frames", "patches")} or None
        logits, aux = model.forward(params, batch["tokens"], extra=extra,
                                    window=window, remat=remat)
        # align label length with logits (vlm prepends patches)
        labels = batch["labels"]
        S = logits.shape[1]
        if labels.shape[1] < S:  # image prefix positions carry no loss
            pad = -jnp.ones((labels.shape[0], S - labels.shape[1]), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        # shift: predict token t+1 from position t
        loss = lm_loss(logits[:, :-1], labels[:, 1:])
        return loss + aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig, *, window: int = 0,
                    remat: bool = False, num_microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``num_microbatches`` > 1 enables gradient accumulation (scan over microbatches,
    f32 grad accumulator) — the standard way to fit the 4k x 256 training shapes'
    activation footprint on a 256-chip pod (DESIGN §4 / EXPERIMENTS §Dry-run).
    """
    # remat is applied per BLOCK inside the layer scan (see Model.forward) — a
    # loss-level checkpoint still leaves the scan storing per-layer intermediates
    loss_fn = make_loss_fn(model, window=window, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if num_microbatches <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            def resh(x):
                return x.reshape((num_microbatches, x.shape[0] // num_microbatches)
                                 + x.shape[1:])

            micro = jax.tree.map(resh, batch)

            def body(acc, mb):
                (l, p), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, (l, p)

            zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            grads, (ls, ps) = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = jnp.mean(ls)
            parts = jax.tree.map(jnp.mean, ps)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = dict(parts, total=loss, **om)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return dict(parts, total=loss)

    return eval_step


def init_train(cfg: ModelConfig, key, opt_cfg: Optional[AdamWConfig] = None,
               dtype=jnp.float32):
    model = build_model(cfg)
    params = model.init(key, dtype)
    opt_state = init_adamw(params)
    return model, params, opt_state
