"""Checkpointing: path-flattened npz + JSON manifest.

Sharding-aware in the single-controller sense: arrays are pulled with
``jax.device_get`` (which assembles fully-addressable shardings) and restored with
``jax.device_put`` against the target sharding, so a checkpoint written under one
mesh restores under another.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, step: int, params, opt_state=None,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(path, exist_ok=True)
    fn = os.path.join(path, f"ckpt_{step:08d}")
    payload = {"params/" + k: v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({"opt/" + k: v for k, v in _flatten(opt_state).items()})
    np.savez(fn + ".npz", **payload)
    manifest = {"step": step, "n_arrays": len(payload),
                "bytes": int(sum(v.nbytes for v in payload.values())),
                "extra": extra or {}}
    with open(fn + ".json", "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(path, "latest"), "w") as f:
        f.write(f"{step:08d}")
    return fn + ".npz"


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "latest")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(path: str, step: int, params_template,
                       opt_template=None, shardings=None):
    """Restore into the structure of the given templates. ``shardings`` optionally
    maps the params pytree to jax.sharding.Sharding for resharded restore."""
    fn = os.path.join(path, f"ckpt_{step:08d}.npz")
    data = np.load(fn)

    def rebuild(template, prefix, shard_tree=None):
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (jax.tree.leaves(shard_tree)
                        if shard_tree is not None else [None] * len(leaves_p))
        out = []
        for (path_k, leaf), sh in zip(leaves_p, shard_leaves):
            key = prefix + "/".join(_path_str(p) for p in path_k)
            arr = data[key]
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(jax.tree.structure(template), out)

    params = rebuild(params_template, "params/", shardings)
    opt = rebuild(opt_template, "opt/") if opt_template is not None else None
    manifest = json.load(open(os.path.join(path, f"ckpt_{step:08d}.json")))
    return params, opt, manifest
