"""Sharding rules: param/state pytrees -> PartitionSpec trees.

Policy (DESIGN §4):
  * batch dims over ('pod','data'); 'model' carries tensor parallelism,
  * 2-D weights: input dim over 'data' (FSDP), output dim over 'model' (TP),
    flipped for output projections so activations stay batch-major,
  * MoE experts over 'model' (expert parallelism) when the expert count divides the
    axis, otherwise fall back to TP over the expert FFN dim,
  * anything that does not divide cleanly is replicated (never an error) — the same
    rule set serves the 1-device CPU mesh, 16x16 and 2x16x16.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _fits(dim: int, axes, sizes) -> bool:
    prod = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        if a not in sizes:
            return False
        prod *= sizes[a]
    return dim % prod == 0


def _sanitize(spec: P, shape, sizes) -> P:
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries[: len(shape)]):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        kept = tuple(a for a in axes if a in sizes)
        if kept and _fits(shape[i], kept, sizes):
            out.append(kept if len(kept) > 1 else kept[0])
        else:
            out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# weight-name -> base spec (before stacking/sanitation). `B` marks batch axes.
_IN_OUT = P("data", "model")     # (d_in, d_out)
_OUT_IN = P("model", "data")     # (d_out_in-major): output projections


def _param_rule(path_names, name: str, shape, sizes) -> tuple:
    """-> (base_spec, semantic_rank). Leading dims beyond semantic_rank are stacked
    layer storage and get None."""
    in_moe = "moe" in path_names and "shared" not in path_names
    if name == "embed":
        return P("model", "data"), 2
    if name == "unembed":
        return P("data", "model"), 2
    if name in ("wq", "wk", "wv", "up_proj", "in_proj", "w_gates", "w_if"):
        return _IN_OUT, 2
    if name in ("wo", "down_proj", "out_proj"):
        return _OUT_IN, 2
    if name in ("w_gate", "w_up"):
        if in_moe:  # experts (E, d, f): EP over 'model', expert-FFN dim f over
            # 'data'. Sharding f (not d) keeps every contraction local: the
            # e*d->f matmul has replicated d on both operands, and the f
            # contraction in w_down psums a small (E,C,d) — no per-layer
            # weight all-gather (EXPERIMENTS §Perf, kimi train iteration 2).
            E = shape[-3]
            if _fits(E, ("model",), sizes):
                return P("model", None, "data"), 3
            return P(None, "data", "model"), 3
        return _IN_OUT, 2
    if name == "w_down":
        if in_moe:  # (E, f, d)
            E = shape[-3]
            if _fits(E, ("model",), sizes):
                return P("model", "data", None), 3
            return P(None, "model", "data"), 3
        return _OUT_IN, 2
    if name == "router":
        return P("data", None), 2
    if name == "conv_w":
        return P(None, "model"), 2
    if name in ("conv_b", "dt_bias", "D", "bq", "bk", "bv"):
        return P("model"), 1
    if name in ("A_log", "x_proj"):
        return P("model", None), 2
    if name == "dt_proj":
        return P(None, "model"), 2
    return P(), 0  # norms, gate biases, r_gates, q_norm/k_norm: replicated


def param_specs(params, mesh: Mesh, *, fsdp: bool = True, tp: bool = True):
    """PartitionSpec tree for a param pytree (works on ShapeDtypeStructs).

    ``fsdp=False`` drops the 'data'-axis weight sharding (weights replicated across
    the data axis, TP only); ``tp=False`` additionally drops the 'model' axis
    (pure data parallelism: fully replicated weights). Small models on a big mesh
    want pure DP — per-use weight all-gathers / per-projection psums dominate their
    tiny compute otherwise (EXPERIMENTS §Perf, xlstm-350m).
    """
    sizes = {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)}

    def rule(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        name = ""
        for n in reversed(names):
            if n and not n.isdigit():
                name = n
                break
        shape = leaf.shape
        base, rank = _param_rule(names, name, shape, sizes)
        if not fsdp:
            base = P(*[None if e == "data" else e for e in base])
        if not tp:
            base = P(*[None if e == "model" else e for e in base])
        lead = len(shape) - rank
        spec = P(*(((None,) * lead) + tuple(base))) if lead > 0 else base
        return _sanitize(spec, shape, sizes)

    return jax.tree_util.tree_map_with_path(rule, params)


def state_specs(state, mesh: Mesh, batch: int, *, kv_shard: str = "replicated"):
    """PartitionSpec tree for decode state (KV caches + recurrent states).

    ``kv_shard`` controls how the attention KV cache uses the 'model' axis on top
    of the batch sharding (EXPERIMENTS §Perf, kimi decode_32k iterations):
      'replicated' — baseline: cache replicated across 'model',
      'head_dim'   — head_dim over 'model' (contraction-sharded attention),
      'window'     — cache window over 'model' (sequence-sharded flash decode).
    """
    sizes = {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)}
    ba = batch_axes(mesh)

    def rule(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v", "cross_k", "cross_v"):
            if kv_shard == "head_dim":
                base = (P(ba, None, None, "model") if batch > 1
                        else P(None, "data", None, "model"))
            elif kv_shard == "window":
                base = (P(ba, "model", None, None) if batch > 1
                        else P(None, ("data", "model"), None, None))
            else:
                base = (P(ba, None, None, None) if batch > 1
                        else P(None, "data", None, None))
        elif name == "h" and nd >= 3:       # mamba (B, d_in, N)
            base = P(ba, "model", None)
        elif name == "conv":                 # (B, dc-1, d_in)
            base = P(ba, None, "model")
        elif name == "C":                    # mlstm (B, H, hd, hd)
            base = P(ba, "model", None, None)
        elif name == "n" and nd == 3:
            base = P(ba, "model", None)
        elif name in ("c", "n", "h", "m"):   # slstm (B, d_in)
            base = P(ba, "model")
        else:
            base = P()
        if len(base) < nd and nd == len(base) + 1:   # stacked repeats
            base = P(*((None,) + tuple(base)))
        return _sanitize(base, shape, sizes)

    return jax.tree_util.tree_map_with_path(rule, state)


def data_specs(batch_dict, mesh: Mesh, *, batch_over_model: bool = False):
    ba = batch_axes(mesh)
    if batch_over_model:
        ba = ba + ("model",)      # pure-DP small models: batch over every axis
    sizes = {a: int(s) for a, s in zip(mesh.axis_names, mesh.devices.shape)}

    def rule(_path, leaf):
        base = P(ba, *([None] * (len(leaf.shape) - 1)))
        return _sanitize(base, leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(rule, batch_dict)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
