"""FleetServer: RaLMSpec speculation rounds for N concurrent requests with
cross-request batched verification.

The paper batches one request's speculative queries into a single KB call
(§A.1: batched retrieval is near-constant-cost for EDR/SR). The fleet extends
that lever across requests: each round, every live slot runs its speculation
stride (lockstep batched decode via BatchedServeEngine), then ALL slots'
verification queries merge into ONE batched KB call. Per-request verification
cost becomes model_latency(sum of strides) / N — the §A.1 shape rewards this
directly, which is what bench_fleet.py measures.

The merged call is backend-agnostic: it goes through ``retriever.retrieve``,
which delegates execution to the retrieval-backend layer
(`repro.retrieval.backends`) — with ``--retriever-backend sharded`` the one
merged verification call per round executes as ONE collective program over
the KB shards (`retrieval/sharded.py`), sync or async/pipelined alike
(tests/test_backends.py asserts calls == collectives == rounds + 1).

Output preservation holds per slot: each slot owns a full Algorithm-1
:class:`~repro.core.ralmspec.RequestState` (cache, OS^3, ledger), verification
compares against the same KB ground truth, and rollback restores only that
slot's row of the batched state. Fleet-served outputs are byte-identical to
per-request RaLMSeq outputs (tests/test_output_preservation.py).

A speculation round (``_run_round``) is defined over the *currently live* slot
set, not a fixed batch width: FleetServer.serve feeds it a fixed request group
until every member finishes, while :class:`ContinuousFleetServer`
(repro.serving.continuous) feeds it whatever slots hold admitted requests this
instant — admitting queued requests into freed slots between rounds and
retiring finished ones, so slots never idle while work is waiting. Per-request
token budgets (``RequestState.max_new``) are honored per slot, which is what
lets heterogeneous-length requests share a fleet without the short ones
padding out to the longest.

Async (pipelined) fleet rounds — the fleet form of the paper's +A (§4,
Fig. 3): with ``async_rounds`` on, ``_run_round`` becomes a two-stage
pipeline. Stage one runs the round's lockstep speculation and SUBMITS the
merged verification KB call to a worker thread (the in-flight-verification
handle); while that call is in flight, the fleet immediately begins round
t+1's lockstep speculation stride from the caches (the *overlap* stride).
When the call completes, the per-slot split runs as usual — and any
mismatched slot has its overlapped speculation invalidated (the restore to
its round-t snapshot rewinds the overlapped steps too; a correction stride
follows), while fully-verified slots keep their overlapped work as a
multi-step carry (``RequestState.carry``) that pre-fills their next round.
Outputs stay byte-identical per slot (tests/test_async_fleet.py): overlapped
speculation is exactly as revocable as in-round speculation.

The overlap is adaptive, gated on the estimated verification latency vs a
speculation sub-step (``rcfg.async_gate_ratio``, same rule as the
single-request path): +A hurts cheap retrievers (ADR, paper Table 4), so
when b_est is small the round degrades gracefully to the synchronous shape.
On the analytic timeline an overlapped round pays the paper's ideal
``a_stage1 + max(a_overlap, b)`` instead of ``a_stage1 + a_overlap' + b`` —
carried steps are never re-charged. Per-slot OS^3 instances switch to the
async objective and observe the amortized ``b / n_participants``.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.configs.base import RaLMConfig
from repro.core.cache import SharedRetrievalCache
from repro.core.ralmspec import (RequestState, ServeResult, _ServerBase,
                                 dedup_queries)
from repro.retrieval.faults import RetrievalFailed
from repro.serving.workload import Workload, default_workload


@dataclass
class FleetResult:
    """Per-request ledgers plus the fleet-shared timeline."""

    results: List[ServeResult]
    wall_time: float = 0.0
    analytic_time: float = 0.0
    rounds: int = 0
    kb_calls: int = 0
    kb_queries: int = 0
    # in-round verification dedup ledger: rows actually sent to the KB across
    # all merged calls vs rows the byte-identical-query collapse saved
    merged_rows: int = 0
    merged_rows_saved: int = 0
    # fault-tolerance ledger (tests/test_faults.py). Attempt counters are
    # fleet-shared like kb_calls: KB-call attempts that raised and were
    # retried (kb_errors), attempts that overran the per-call deadline
    # (kb_timeouts), and calls that exhausted the whole retry budget
    # (kb_failures). degraded_rounds counts rounds that fell back to
    # speculation-only after such a failure; worker_crashes counts async
    # verification calls that raised on the worker and were re-run
    # synchronously; seed_failures counts failed admission-seed calls (those
    # only cost a cold speculation cache — never correctness).
    kb_errors: int = 0
    kb_timeouts: int = 0
    kb_failures: int = 0
    seed_failures: int = 0
    degraded_rounds: int = 0
    worker_crashes: int = 0
    # measured wall-clock overlap ledger (monotonic clock, this box — NOT the
    # modeled timeline): total wall seconds spent inside merged verification
    # KB calls (verify_wall_s — accumulated in sync AND async rounds), wall
    # seconds of the overlapped speculation strides the main thread ran while
    # a call was in flight (overlap_wall_s), and the intersection of the two
    # span sets (measured_overlap_s) — the seconds during which the worker's
    # BLAS/device scan and the LM stride were DEMONSTRABLY concurrent. Only
    # async rounds with the gate open contribute to the latter two; sync
    # fleets leave them at exactly 0. measured_overlap_s <=
    # min(verify_wall_s, overlap_wall_s) by construction.
    verify_wall_s: float = 0.0
    overlap_wall_s: float = 0.0
    measured_overlap_s: float = 0.0

    @property
    def degraded_requests(self) -> int:
        """Requests whose outputs are exempt from byte-parity because a
        verification call failed for good while they were live."""
        return sum(1 for r in self.results if r.status == "degraded")

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    def throughput(self, modeled: bool = True) -> float:
        """Aggregate tokens/s across the fleet (modeled timeline by default —
        the paper-hardware batched-retrieval shape; wall on this 1-core box)."""
        t = self.analytic_time if modeled else self.wall_time
        return self.total_tokens / max(t, 1e-9)

    @property
    def latency(self) -> float:
        """Per-request latency: lockstep rounds finish together, so every
        request observes the shared fleet timeline."""
        return self.analytic_time


class FleetServer(_ServerBase):
    """Drives N RequestStates in lockstep over a BatchedServeEngine.

    ``async_rounds`` pipelines the rounds (see module docstring): None (the
    default) follows ``rcfg.async_verification`` — the fleet now honors the
    paper's +A configuration — while True/False force it regardless of the
    variant string. The synchronous path is byte-for-byte the previous
    behavior.

    ``workload`` selects the Algorithm-1 specifics the round loop runs
    (:mod:`repro.serving.workload`): None picks by ``rcfg.knnlm`` —
    :class:`~repro.serving.workload.IterativeRaLMWorkload` (byte-parity) or
    :class:`~repro.serving.workload.KNNLMWorkload` (token-match). Everything
    workload-shared — merged KB call, dedup ledger, shared cache tier, fault
    shell, async overlap — lives here."""

    def __init__(self, engine, retriever, rcfg: RaLMConfig,
                 encoder=None, chunk_len: int = 64,
                 async_rounds: Optional[bool] = None,
                 shared_cache: Optional[SharedRetrievalCache] = None,
                 workload: Optional[Workload] = None):
        super().__init__(engine, retriever, rcfg, encoder, chunk_len,
                         shared_cache=shared_cache)
        self.workload = workload if workload is not None else default_workload(rcfg)
        self.workload.validate(self)
        self.async_rounds = (rcfg.async_verification if async_rounds is None
                             else async_rounds)
        self._pool = (ThreadPoolExecutor(max_workers=1)
                      if self.async_rounds else None)
        self._os3_async = self.async_rounds     # fleet OS^3 objective (A.2)
        self._inflight = None                   # in-flight verification handle
        # monotonic dedup ledger; serve() diffs it into the result object
        self.merged_rows = 0
        self.merged_rows_saved = 0
        # monotonic count of failed admission-seed calls (same diff pattern)
        self.seed_failures = 0
        # measured wall-clock overlap ledger (same diff pattern; see
        # FleetResult). time.monotonic spans: the worker records its KB-call
        # span in _verify_span, the round loop intersects it with the
        # overlapped stride's span. Both numpy BLAS and XLA release the GIL,
        # so the spans genuinely interleave even on one core — a positive
        # intersection is measured (not modeled) concurrency.
        self.verify_wall = 0.0
        self.overlap_wall = 0.0
        self.overlap_measured = 0.0
        self._verify_span = None

    # ---- per-slot predicates (fleet versions of _ServerBase._done/_budget) ---------
    # The inherited single-request forms read engine.finished/.generated, which on
    # a BatchedServeEngine are methods, not properties — fail loudly rather than
    # silently treating bound methods as truthy.
    def _done(self):
        raise NotImplementedError("FleetServer is per-slot: use _slot_done(b)")

    def _budget(self):
        raise NotImplementedError("FleetServer is per-slot: use _slot_budget(b)")

    def _slot_done(self, b: int, st: RequestState) -> bool:
        return (self.engine.finished(b)
                or len(self.engine.generated(b)) >= st.budget_limit(self.rcfg))

    def _slot_budget(self, b: int, st: RequestState) -> int:
        return st.budget_limit(self.rcfg) - len(self.engine.generated(b))

    def _extra_verification_queries(self, spec_elapsed: float) -> List:
        """Ride-along queries appended to the round's merged verification KB
        call. The fixed fleet has none; ContinuousFleetServer uses this to
        pre-seed queued requests' caches without a separate KB call.
        ``spec_elapsed`` is the round's speculation time so far — the call is
        issued that far past the round-start clock, so requests that arrived
        mid-round are eligible to ride it."""
        return []

    def _absorb_extra_verification(self, ids_rows, sc_rows) -> None:
        pass

    def _drain_inflight(self) -> None:
        """Join any in-flight verification call. ``_run_round`` always joins
        (and handles the failure of) its own call before returning, so
        between rounds this is a no-op — but slot-population mutations
        (admit/retire) go through it anyway so the invariant survives future
        reshaping of the pipeline. A leftover handle only exists on
        exceptional paths, so a raise from it is swallowed here: the drain's
        job is to make the join happen, and re-raising would poison
        ``close()`` with a failure the round loop already recovered from."""
        if self._inflight is not None:
            fut, self._inflight = self._inflight, None
            try:
                fut.result()
            except Exception:
                pass

    def close(self) -> None:
        """Release the verification worker thread. Long-lived processes that
        build servers per request group should call this (or use the server
        as a context manager) — the pool otherwise lives until process
        exit."""
        try:
            self._drain_inflight()
        finally:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _dedup(self, queries):
        """Collapse byte-identical queries before a merged KB call (gated on
        ``rcfg.dedup_verification``). -> (unique_queries, inverse-or-None);
        scatter rows back with ``rows[inverse]``. Ledger counts live here so
        both the fixed and continuous serve loops can diff them."""
        if not self.rcfg.dedup_verification:
            self.merged_rows += len(queries)
            return list(queries), None
        uniq, inv = dedup_queries(queries)
        self.merged_rows += len(uniq)
        self.merged_rows_saved += len(queries) - len(uniq)
        return uniq, inv

    def _verify_merged(self, queries, k: int):
        """The round's merged verification KB call + shared-tier publish,
        behind the fault-tolerance shell (deadline + backoff retry — raises
        RetrievalFailed when the budget runs out; the round loop degrades).
        With async rounds this body runs on the worker thread — the publish
        is what lets slot t+1's overlapped speculation hit results verified
        for slot t, and it is safe because the shared tier locks. The
        monotonic span of the call is recorded either way (the round loop
        intersects it with the overlapped stride to measure real
        concurrency); reading it from the main thread is safe only after the
        future resolves."""
        t0 = time.monotonic()
        try:
            ids, scores = self._retrieve_guarded(queries, k)
            self._shared_put(queries, ids, scores)
        finally:
            t1 = time.monotonic()
            self._verify_span = (t0, t1)
            self.verify_wall += t1 - t0
        return ids, scores

    def _seed_slots(self, pairs) -> float:
        """Algorithm 1 line 4, cross-request batched: ONE KB call seeds every
        given (slot, state) pair's cache — deduplicated, so N identical
        prompts cost one KB row. Returns the modeled latency of the call
        (what the batched retrieval would cost on paper hardware).

        A seed call that fails after retries is absorbed, not raised: seeding
        only warms speculation (a cold cache speculates -1 and verification
        corrects), so the slots start cold and stay output-identical — the
        cheapest degradation in the stack (``seed_failures`` on the result)."""
        if not pairs:
            return 0.0
        q0 = [self._query_tokens(self.engine.tokens[b]) for b, _ in pairs]
        uniq, inv = self._dedup(q0)
        try:
            ids_u, sc_u = self._verify_merged(uniq,
                                              self.workload.verify_k(self.rcfg))
        except RetrievalFailed:
            self.seed_failures += 1
            return (self.retriever.stats.model_latency(len(uniq))
                    + self._take_ft_overhead())
        ids0 = ids_u if inv is None else ids_u[inv]
        sc0 = sc_u if inv is None else sc_u[inv]
        for (b, st), row, srow in zip(pairs, ids0, sc0):
            self.workload.seed_from_merged(self, st, row, srow)
            # per-slot ledger: batched KB calls the slot PARTICIPATED in (so a
            # slot's kb_calls is comparable to single-request RaLMSpec's
            # 1 initial + 1 per round); FleetResult.kb_calls counts the actual
            # shared calls, so the per-slot sum exceeds it by design.
            st.res.kb_calls += 1
            st.res.kb_queries += 1
        return (self.retriever.stats.model_latency(len(uniq))
                + self._take_ft_overhead())

    def _lockstep_substep(self, doers: Sequence[int], states) -> tuple:
        """One batched speculation sub-step over ``doers`` — dispatched to the
        workload (iterative RaLM: doc swap + ONE batched generation stride;
        KNN-LM: cache-neighbour interpolation + ONE batched single-token
        advance). Returns ``({slot: (snap, query, spec, aux)},
        wall_seconds)``."""
        return self.workload.speculate_step(self, doers, states)

    def _overlap_speculate(self, slots: Sequence[int], states,
                           strides: Dict[int, int], a_est: float,
                           b_est: float, fut=None) -> tuple:
        """Round t+1's lockstep speculation, run while round t's merged
        verification call is in flight. Steps are recorded per slot as
        TENTATIVE carry steps (never into the round scratch): a slot that
        round t rolls back discards them wholesale.

        Two bounds compose. The MODELED window: sub-steps run only while the
        next one is expected to still fit under ``b_est`` — those steps are
        FREE on the analytic timeline (the round pays ``max(a_overlap, b)``),
        so an overlapped round costs no more than a synchronous one up to
        a_est/b_est estimation error, even when every slot's overlap is later
        invalidated; inside it a slot speculates at most its next stride (the
        carry that pre-fills round t+1). The IN-FLIGHT extension: when the
        verification future is handed in and has NOT resolved yet, keep
        speculating past both the window and the per-slot stride cap, up to
        each slot's remaining token budget — the worker is still inside its
        KB scan / service wait (GIL released), so on the wall clock those
        deep steps are reclaimed idle time, and every one of them pre-fills a
        future stride, so surviving deep carries collapse whole rounds (and
        their merged KB calls). ``fut.done()`` is the (cheap) oracle: a call
        that returns quickly grants no extra depth, a slow one — big KB,
        remote/disk service latency — grants a lot. ``rcfg.async_min_overlap``
        forces that many sub-steps regardless of the window (tests use it to
        exercise the carry paths on stacks whose retrieval is too cheap to
        hide anything).

        Analytic accounting: overlapped sub-steps are charged at ``a_est``
        (the round's calibrated uncontended per-step cost), NOT at their
        measured wall — on this 1-core container the verification worker's
        BLAS scan contends with the overlapped LM work, roughly doubling its
        wall time, which the paper's parallel hardware would not see. This is
        the same strategy the paper itself uses for +A's analytic ideal under
        the GIL (§5.1); wall-clock totals report the contended truth, as
        everywhere. Returns
        ``({slot: [(snap, query, spec, a_est, aux), ...]}, modeled_seconds)``
        — 5-tuples matching ``RequestState.record_step``, so carried steps
        replay through ``begin_round`` with their workload aux intact (KNN-LM
        verifies a carried token from its recorded logits a round later)."""
        overlap: Dict[int, List[tuple]] = {b: [] for b in slots}
        n_sub = 0
        while True:
            in_flight = fut is not None and not fut.done()
            if (n_sub >= self.rcfg.async_min_overlap
                    and (n_sub + 1) * a_est > b_est
                    and not in_flight):
                break                       # window overrun and call resolved
            doers = [b for b in slots
                     if (len(overlap[b]) < strides[b] or in_flight)
                     and not self._slot_done(b, states[b])]
            if not doers:
                break
            steps, _ = self._lockstep_substep(doers, states)
            n_sub += 1
            for b in doers:
                snap, q, spec, aux = steps[b]
                overlap[b].append((snap, q, spec, a_est, aux))
        return {b: ov for b, ov in overlap.items() if ov}, n_sub * a_est

    def _run_round(self, live: Sequence[int], states, fleet) -> tuple:
        """One Algorithm-1 speculation round over the CURRENTLY live slot set.

        ``live`` is any subset of engine slots; ``states`` maps slot id ->
        RequestState (a list works for the fixed fleet, a dict for the
        continuous fleet). Two-stage pipeline:

          stage 1 — lockstep speculation sub-steps (carried overlap steps from
              the previous round pre-fill each slot's scratch), then the ONE
              merged verification KB call: submitted to the worker thread when
              async rounds are on and the adaptive gate passes, issued inline
              otherwise;
          stage 2 — while the call is in flight, the next round's lockstep
              overlap stride; then join, per-slot split, carry assignment /
              invalidation, and the batched correction stride for whichever
              slots mis-speculated.

        Returns ``(analytic_seconds, n_participants)``; ``fleet`` only needs a
        ``rounds`` counter (FleetResult or ContinuousResult).
        """
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        analytic = 0.0
        strides = {b: max(states[b].stride(rcfg), 1) for b in live}
        for b in live:
            states[b].begin_round()

        # ---- stage 1: lockstep speculation, one batched decode per sub-step -
        while True:
            doers = [b for b in live
                     if len(states[b].specs) < strides[b]
                     and not self._slot_done(b, states[b])]
            if not doers:
                break
            steps, a_sub = self._lockstep_substep(doers, states)
            # the sub-step runs batched: the fleet pays it once, every
            # participant's OS^3 sees it as its per-step a
            analytic += a_sub
            for b in doers:
                snap, q, spec, aux = steps[b]
                states[b].record_step(snap, q, spec, a_sub, aux)
                if states[b].os3:
                    states[b].os3.record_speculation(a_sub)

        participants = [b for b in live if states[b].specs]
        if not participants:
            return analytic, 0

        # ---- cross-request batched verification: ONE KB call per round ------
        # Ride-along queries (continuous batching pre-seeds queued requests'
        # caches this way) share the same call — batched retrieval is
        # near-constant-cost (§A.1), so they are almost free. With async
        # rounds they attach to the in-flight call at submission time.
        extra = self._extra_verification_queries(analytic)
        all_queries = [q for b in participants
                       for q in self.workload.build_verification_queries(states[b])]
        all_queries += list(extra)
        k = self.workload.verify_k(rcfg)
        # in-round dedup: one KB row per UNIQUE query in the merged call;
        # rows scatter back to slots below. The latency model sees the
        # deduplicated width — that's the saving.
        uniq, inv = self._dedup(all_queries)

        # adaptive overlap gate, the fleet form of the single path's rule:
        # only pipeline when the modeled verification latency is worth hiding
        # (ADR's cheap probes make the overlap pure downside, paper Table 4)
        overlap: Dict[int, List[tuple]] = {}
        overlap_a = 0.0
        gt_u = sc_u = None
        if self._pool is not None:
            a_all = [a for b in participants for a in states[b].a_times]
            a_est = sum(a_all) / max(len(a_all), 1)
            b_est = r.stats.model_latency(len(uniq))
            if b_est > rcfg.async_gate_ratio * a_est:
                # ---- stage 2: overlap the call with round t+1's stride ------
                self._verify_span = None
                self._inflight = self._pool.submit(
                    self._verify_merged, uniq, k)
                t_ov0 = time.monotonic()
                try:
                    overlap, overlap_a = self._overlap_speculate(
                        participants, states, strides, a_est, b_est,
                        fut=self._inflight)
                finally:
                    t_ov1 = time.monotonic()
                    # clear the handle BEFORE joining: if the worker call
                    # raised, a still-set handle would poison _drain_inflight
                    # and close() with the same re-raise
                    fut, self._inflight = self._inflight, None
                try:
                    gt_u, sc_u = fut.result()
                    # measured concurrency: the worker's KB-call span
                    # (written before the future resolved — the join is the
                    # happens-before edge) intersected with the overlapped
                    # stride's span, both on the monotonic clock
                    if self._verify_span is not None:
                        v0, v1 = self._verify_span
                        self.overlap_wall += t_ov1 - t_ov0
                        self.overlap_measured += max(
                            0.0, min(v1, t_ov1) - max(v0, t_ov0))
                except Exception:
                    # worker crash recovery: the in-flight verification died
                    # (RetrievalFailed after its retries, or anything else the
                    # worker hit). Discard the overlapped stride exactly as a
                    # rollback would — restoring each slot's first overlap
                    # snapshot rewinds the tentative steps — then fall back to
                    # a synchronous verification round below, which gets a
                    # fresh retry budget. The round, not the server, dies
                    # last: only a failed *synchronous* call degrades.
                    fleet.worker_crashes += 1
                    for b, steps in overlap.items():
                        eng.restore(b, steps[0][0])
                        states[b].res.carry_invalidations += 1
                    overlap, overlap_a = {}, 0.0
        if gt_u is None:                        # sync round / closed gate / fallback
            try:
                gt_u, sc_u = self._verify_merged(uniq, k)
            except RetrievalFailed:
                if not rcfg.degrade_on_failure:
                    raise
                # ---- graceful degradation: speculation-only round -----------
                # The KB is unreachable for good (this round): accept every
                # slot's speculated stride as served output — no rollback, no
                # cache update — and mark the requests degraded, which exempts
                # them from the byte-parity claim (shared-cache/speculation
                # quality only; the stream stays available instead of dying).
                # Ride-along seed queries are dropped (their requests take the
                # dedicated seed path later); OS^3 sees no verification.
                analytic += self._take_ft_overhead()
                fleet.rounds += 1
                fleet.degraded_rounds += 1
                self._absorb_extra_verification([], [])
                for b in participants:
                    st = states[b]
                    n = len(st.specs)
                    st.res.status = "degraded"
                    st.res.rounds += 1
                    st.res.spec_steps += n
                    st.res.strides.append(n)
                return analytic, len(participants)
        gt_all = gt_u if inv is None else gt_u[inv]
        sc_all = sc_u if inv is None else sc_u[inv]
        b_model = r.stats.model_latency(len(uniq))
        # analytic ideal (paper §4, fleet-wide): an overlapped round pays
        # max(a_overlap, b) for the in-flight window; a plain round pays b.
        # Failed attempts (retries/backoff, a crashed worker call) are charged
        # on top at their modeled cost via the guarded call's accumulator.
        analytic += max(overlap_a, b_model) if overlap_a else b_model
        analytic += self._take_ft_overhead()
        fleet.rounds += 1
        if extra:
            self._absorb_extra_verification(gt_all[-len(extra):],
                                            sc_all[-len(extra):])

        # ---- split per slot: cache update, mismatch, carry, bookkeeping -----
        rollbacks = []           # slots needing a correction stride
        corrections = {}         # slot -> workload correction payload
        off = 0
        for b in participants:
            st = states[b]
            n = len(st.specs)
            gt = gt_all[off:off + n]
            sc = sc_all[off:off + n]
            off += n
            m, corr = self.workload.check_and_commit(self, st, gt, sc)
            if st.os3:
                # amortized share: the batched call serves every participant
                st.os3.record_verification(b_model, n, m,
                                           n_participants=len(participants))
            st.res.rounds += 1
            st.res.spec_steps += n
            st.res.strides.append(n)
            st.res.kb_calls += 1
            st.res.kb_queries += n
            if m < n:
                st.res.mismatches += 1
                if overlap.pop(b, None):
                    # the overlapped stride speculated past a wrong step: the
                    # restore below rewinds it along with steps m..n-1
                    st.res.carry_invalidations += 1
                eng.restore(b, st.snaps[m])
                self.workload.apply_correction(self, b, st, corr)
                rollbacks.append(b)
                corrections[b] = corr
            elif b in overlap:
                st.carry = overlap.pop(b)
                st.res.carry_steps += len(st.carry)
                if st.os3:
                    for step in st.carry:
                        st.os3.record_speculation(step[3])

        # ---- corrections: ONE batched engine call for all rollbacks ---------
        if rollbacks:
            tc = time.perf_counter()
            self.workload.correction_stride(self, rollbacks, states, corrections)
            analytic += time.perf_counter() - tc
        return analytic, len(participants)

    def serve(self, prompts: Sequence[Sequence[int]],
              max_new: Optional[Sequence[int]] = None) -> FleetResult:
        """Serve a fixed request group to completion. ``max_new`` optionally
        gives per-request token budgets (default: rcfg.max_new_tokens for all —
        the continuous path is the one that exercises heterogeneity, but the
        fixed fleet honors budgets too so the two are benchmark-comparable)."""
        eng, rcfg = self.engine, self.rcfg
        r = self.retriever
        B = len(prompts)
        assert B <= eng.n_slots, f"{B} requests > {eng.n_slots} fleet slots"
        eng.stats.reset()
        r0t = r.stats.time
        r0c, r0q = r.stats.calls, r.stats.queries
        m0, ms0 = self.merged_rows, self.merged_rows_saved
        r0e, r0o, r0f = r.stats.errors, r.stats.timeouts, r.stats.failed_calls
        sf0 = self.seed_failures
        vw0, ow0, mo0 = self.verify_wall, self.overlap_wall, self.overlap_measured
        states = [self._new_request_state(
            rid=b, max_new=max_new[b] if max_new is not None else None)
            for b in range(B)]
        fleet = FleetResult(results=[st.res for st in states])
        t0 = time.perf_counter()

        for b, p in enumerate(prompts):
            eng.start(b, list(p)[-rcfg.max_prompt_len:])
        analytic = self._seed_slots([(b, states[b]) for b in range(B)])

        while True:
            # NB: a slot with a pending carry is holding an UNVERIFIED
            # overlapped stride — it must stay live past budget/EOS until the
            # carry is verified (and corrected if wrong), or output
            # preservation breaks on the final stride (same rule as the
            # single-request loop).
            live = [b for b in range(B)
                    if not self._slot_done(b, states[b]) or states[b].carry]
            if not live:
                break
            a, n_part = self._run_round(live, states, fleet)
            analytic += a
            if n_part == 0:
                break

        fleet.wall_time = time.perf_counter() - t0
        fleet.analytic_time = analytic
        fleet.kb_calls = r.stats.calls - r0c
        fleet.kb_queries = r.stats.queries - r0q
        fleet.merged_rows = self.merged_rows - m0
        fleet.merged_rows_saved = self.merged_rows_saved - ms0
        fleet.kb_errors = r.stats.errors - r0e
        fleet.kb_timeouts = r.stats.timeouts - r0o
        fleet.kb_failures = r.stats.failed_calls - r0f
        fleet.seed_failures = self.seed_failures - sf0
        fleet.verify_wall_s = self.verify_wall - vw0
        fleet.overlap_wall_s = self.overlap_wall - ow0
        fleet.measured_overlap_s = self.overlap_measured - mo0
        # per-slot time fields are the SHARED fleet timeline (lockstep rounds
        # finish together): don't sum them across slots — like kb_calls above,
        # summing overcounts by the concurrency factor. Aggregate via
        # FleetResult instead.
        for b, st in enumerate(states):
            st.res.tokens = list(eng.generated(b))
            st.res.wall_time = fleet.wall_time
            st.res.analytic_time = analytic
            st.res.gen_time = eng.stats.gen_time
            st.res.retrieval_time = r.stats.time - r0t
        return fleet
