"""FleetServer: RaLMSpec speculation rounds for N concurrent requests with
cross-request batched verification.

The paper batches one request's speculative queries into a single KB call
(§A.1: batched retrieval is near-constant-cost for EDR/SR). The fleet extends
that lever across requests: each round, every live slot runs its speculation
stride (lockstep batched decode via BatchedServeEngine), then ALL slots'
verification queries merge into ONE batched KB call. Per-request verification
cost becomes model_latency(sum of strides) / N — the §A.1 shape rewards this
directly, which is what bench_fleet.py measures.

Output preservation holds per slot: each slot owns a full Algorithm-1
:class:`~repro.core.ralmspec.RequestState` (cache, OS^3, ledger), verification
compares against the same KB ground truth, and rollback restores only that
slot's row of the batched state. Fleet-served outputs are byte-identical to
per-request RaLMSeq outputs (tests/test_output_preservation.py).

A speculation round (``_run_round``) is defined over the *currently live* slot
set, not a fixed batch width: FleetServer.serve feeds it a fixed request group
until every member finishes, while :class:`ContinuousFleetServer`
(repro.serving.continuous) feeds it whatever slots hold admitted requests this
instant — admitting queued requests into freed slots between rounds and
retiring finished ones, so slots never idle while work is waiting. Per-request
token budgets (``RequestState.max_new``) are honored per slot, which is what
lets heterogeneous-length requests share a fleet without the short ones
padding out to the longest.

Async verification's per-slot carry is not used on the fleet paths:
cross-request batching already amortizes the verification latency the async
carry was hiding, and a per-slot carry would break the shared round clock.
``rcfg.async_verification`` only affects the OS^3 objective it was enabled
for; the fleet ignores the carry machinery.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs.base import RaLMConfig
from repro.core.ralmspec import (RequestState, ServeResult, _ServerBase,
                                 first_mismatch)


@dataclass
class FleetResult:
    """Per-request ledgers plus the fleet-shared timeline."""

    results: List[ServeResult]
    wall_time: float = 0.0
    analytic_time: float = 0.0
    rounds: int = 0
    kb_calls: int = 0
    kb_queries: int = 0

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    def throughput(self, modeled: bool = True) -> float:
        """Aggregate tokens/s across the fleet (modeled timeline by default —
        the paper-hardware batched-retrieval shape; wall on this 1-core box)."""
        t = self.analytic_time if modeled else self.wall_time
        return self.total_tokens / max(t, 1e-9)

    @property
    def latency(self) -> float:
        """Per-request latency: lockstep rounds finish together, so every
        request observes the shared fleet timeline."""
        return self.analytic_time


class FleetServer(_ServerBase):
    """Drives N RequestStates in lockstep over a BatchedServeEngine."""

    # ---- per-slot predicates (fleet versions of _ServerBase._done/_budget) ---------
    # The inherited single-request forms read engine.finished/.generated, which on
    # a BatchedServeEngine are methods, not properties — fail loudly rather than
    # silently treating bound methods as truthy.
    def _done(self):
        raise NotImplementedError("FleetServer is per-slot: use _slot_done(b)")

    def _budget(self):
        raise NotImplementedError("FleetServer is per-slot: use _slot_budget(b)")

    def _slot_done(self, b: int, st: RequestState) -> bool:
        return (self.engine.finished(b)
                or len(self.engine.generated(b)) >= st.budget_limit(self.rcfg))

    def _slot_budget(self, b: int, st: RequestState) -> int:
        return st.budget_limit(self.rcfg) - len(self.engine.generated(b))

    def _extra_verification_queries(self, spec_elapsed: float) -> List:
        """Ride-along queries appended to the round's merged verification KB
        call. The fixed fleet has none; ContinuousFleetServer uses this to
        pre-seed queued requests' caches without a separate KB call.
        ``spec_elapsed`` is the round's speculation time so far — the call is
        issued that far past the round-start clock, so requests that arrived
        mid-round are eligible to ride it."""
        return []

    def _absorb_extra_verification(self, rows) -> None:
        pass

    def _seed_slots(self, pairs) -> float:
        """Algorithm 1 line 4, cross-request batched: ONE KB call seeds every
        given (slot, state) pair's cache. Returns the modeled latency of the
        call (what the batched retrieval would cost on paper hardware)."""
        if not pairs:
            return 0.0
        q0 = [self._query_tokens(self.engine.tokens[b]) for b, _ in pairs]
        ids0, _ = self._retrieve_batch(q0, max(self.rcfg.prefetch_top_k, 1))
        for (b, st), row in zip(pairs, ids0):
            self._cache_insert(st.cache, row)
            # per-slot ledger: batched KB calls the slot PARTICIPATED in (so a
            # slot's kb_calls is comparable to single-request RaLMSpec's
            # 1 initial + 1 per round); FleetResult.kb_calls counts the actual
            # shared calls, so the per-slot sum exceeds it by design.
            st.res.kb_calls += 1
            st.res.kb_queries += 1
        return self.retriever.stats.model_latency(len(pairs))

    def _run_round(self, live: Sequence[int], states, fleet) -> tuple:
        """One Algorithm-1 speculation round over the CURRENTLY live slot set.

        ``live`` is any subset of engine slots; ``states`` maps slot id ->
        RequestState (a list works for the fixed fleet, a dict for the
        continuous fleet). Runs the lockstep speculation sub-steps, the ONE
        merged verification KB call, the per-slot split, and the batched
        correction stride for whichever slots mis-speculated. Returns
        ``(analytic_seconds, n_participants)``; ``fleet`` only needs a
        ``rounds`` counter (FleetResult or ContinuousResult).
        """
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        analytic = 0.0
        strides = {b: max(states[b].stride(rcfg), 1) for b in live}
        for b in live:
            states[b].begin_round()

        # ---- lockstep speculation: one batched decode per sub-step ----------
        while True:
            doers = [b for b in live
                     if len(states[b].specs) < strides[b]
                     and not self._slot_done(b, states[b])]
            if not doers:
                break
            t_sub = time.perf_counter()
            for b in doers:
                snap = eng.snapshot(b)
                q = self._query_tokens(eng.tokens[b])
                ids, _ = states[b].cache.retrieve(q, 1)
                did = int(ids[0])
                if did >= 0:
                    eng.set_doc(b, self._doc(did))
                # did < 0 (cold cache) keeps the slot's previous doc;
                # verification will correct — same as the single path.
                states[b].record_step(snap, q, did, 0.0)
            eng.gen(doers, [min(rcfg.generation_stride,
                                self._slot_budget(b, states[b]))
                            for b in doers])
            a_sub = time.perf_counter() - t_sub
            # the sub-step runs batched: the fleet pays it once, every
            # participant's OS^3 sees it as its per-step a
            analytic += a_sub
            for b in doers:
                states[b].a_times[-1] = a_sub
                if states[b].os3:
                    states[b].os3.record_speculation(a_sub)

        participants = [b for b in live if states[b].specs]
        if not participants:
            return analytic, 0

        # ---- cross-request batched verification: ONE KB call per round ------
        # Ride-along queries (continuous batching pre-seeds queued requests'
        # caches this way) share the same call — batched retrieval is
        # near-constant-cost (§A.1), so they are almost free.
        extra = self._extra_verification_queries(analytic)
        all_queries = [q for b in participants for q in states[b].queries]
        all_queries += list(extra)
        gt_all, _ = self._retrieve_batch(all_queries,
                                         max(rcfg.prefetch_top_k, 1))
        b_model = r.stats.model_latency(len(all_queries))
        analytic += b_model
        fleet.rounds += 1
        if extra:
            self._absorb_extra_verification(gt_all[-len(extra):])

        # ---- split per slot: cache update, mismatch, bookkeeping ------------
        rollbacks = []           # slots needing a correction stride
        off = 0
        for b in participants:
            st = states[b]
            n = len(st.specs)
            gt = gt_all[off:off + n]
            off += n
            for row in gt:
                self._cache_insert(st.cache, row[:max(rcfg.prefetch_top_k, 1)])
            m = first_mismatch(st.specs, gt)
            if st.os3:
                # amortized share: the batched call serves every participant
                st.os3.record_verification(b_model / len(participants), n, m)
            st.res.rounds += 1
            st.res.spec_steps += n
            st.res.strides.append(n)
            st.res.kb_calls += 1
            st.res.kb_queries += n
            if m < n:
                st.res.mismatches += 1
                eng.restore(b, st.snaps[m])
                eng.set_doc(b, self._doc(gt[m][0]))
                rollbacks.append(b)

        # ---- corrections: one batched generation stride for all rollbacks ---
        if rollbacks:
            tc = time.perf_counter()
            eng.gen(rollbacks, [min(rcfg.generation_stride,
                                    self._slot_budget(b, states[b]))
                                for b in rollbacks])
            analytic += time.perf_counter() - tc
        return analytic, len(participants)

    def serve(self, prompts: Sequence[Sequence[int]],
              max_new: Optional[Sequence[int]] = None) -> FleetResult:
        """Serve a fixed request group to completion. ``max_new`` optionally
        gives per-request token budgets (default: rcfg.max_new_tokens for all —
        the continuous path is the one that exercises heterogeneity, but the
        fixed fleet honors budgets too so the two are benchmark-comparable)."""
        eng, rcfg = self.engine, self.rcfg
        r = self.retriever
        B = len(prompts)
        assert B <= eng.n_slots, f"{B} requests > {eng.n_slots} fleet slots"
        eng.stats.reset()
        r0t = r.stats.time
        r0c, r0q = r.stats.calls, r.stats.queries
        states = [self._new_request_state(
            rid=b, max_new=max_new[b] if max_new is not None else None)
            for b in range(B)]
        fleet = FleetResult(results=[st.res for st in states])
        t0 = time.perf_counter()

        for b, p in enumerate(prompts):
            eng.start(b, list(p)[-rcfg.max_prompt_len:])
        analytic = self._seed_slots([(b, states[b]) for b in range(B)])

        while True:
            live = [b for b in range(B) if not self._slot_done(b, states[b])]
            if not live:
                break
            a, n_part = self._run_round(live, states, fleet)
            analytic += a
            if n_part == 0:
                break

        fleet.wall_time = time.perf_counter() - t0
        fleet.analytic_time = analytic
        fleet.kb_calls = r.stats.calls - r0c
        fleet.kb_queries = r.stats.queries - r0q
        # per-slot time fields are the SHARED fleet timeline (lockstep rounds
        # finish together): don't sum them across slots — like kb_calls above,
        # summing overcounts by the concurrency factor. Aggregate via
        # FleetResult instead.
        for b, st in enumerate(states):
            st.res.tokens = list(eng.generated(b))
            st.res.wall_time = fleet.wall_time
            st.res.analytic_time = analytic
            st.res.gen_time = eng.stats.gen_time
            st.res.retrieval_time = r.stats.time - r0t
        return fleet
