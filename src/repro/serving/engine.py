"""Serving engine: prefill + greedy decode with snapshot/rollback.

RaLMSpec needs three properties from the LM side (paper §3 + our DESIGN §5):
  * deterministic generation (greedy) — the output-preservation proof needs it,
  * cheap state snapshots at speculation-step boundaries — JAX arrays are immutable,
    so a snapshot is just (context length, position, state pytree *reference*): O(1),
  * doc-conditioned generation à la Ram et al. 2023: the latest retrieved chunk is
    prepended to the prompt, *replacing* the previous one, which invalidates the KV
    cache ⇒ re-prefill. This is the baseline's dominant G-cost, exactly as the paper
    describes it.

Shape discipline for jit reuse: documents are padded/truncated to a fixed chunk
length and generation advances in fixed strides, so prefill shapes recur across
requests and the jit cache stays small.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class EngineStats:
    prefill_time: float = 0.0
    decode_time: float = 0.0
    prefills: int = 0
    decodes: int = 0

    @property
    def gen_time(self) -> float:        # the paper's G component
        return self.prefill_time + self.decode_time

    def reset(self):
        self.prefill_time = self.decode_time = 0.0
        self.prefills = self.decodes = 0


class ServeEngine:
    """Single-request greedy engine over a Model."""

    def __init__(self, model: Model, params, *, cache_window: int = 2048,
                 eos_id: int = -1, extra: Optional[dict] = None):
        self.model = model
        self.params = params
        self.W = cache_window
        self.eos_id = eos_id
        self.extra = extra
        self.stats = EngineStats()
        self._decode_jit = jax.jit(
            lambda p, st, tok, pos: model.decode_step(p, st, tok, pos))
        self._prefill_jit = jax.jit(
            lambda p, toks: model.prefill(p, toks, extra=extra,
                                          window_cache=self.W))
        # mutable per-request state
        self.doc: Tuple[int, ...] = ()
        self.tokens: List[int] = []        # prompt + generated (doc NOT included)
        self.n_prompt = 0
        self._state = None
        self._pos = None

    def warm(self, lengths: Sequence[int]) -> None:
        """Precompile prefill for every context length in the serving grid (and one
        decode step) so wall-clock benchmarks measure compute, not XLA compiles.
        Both RaLMSeq and RaLMSpec use the same closed set of shapes (fixed doc chunk
        + prompt + i * generation_stride), so warming is system-neutral."""
        for L in sorted(set(int(x) for x in lengths)):
            toks = jnp.zeros((1, L), jnp.int32)
            last, state, pos = self._prefill_jit(self.params, toks)
            jax.block_until_ready(last)
        logits, _ = self._decode_jit(self.params, state,
                                     jnp.zeros((1,), jnp.int32), pos)
        jax.block_until_ready(logits)

    # ---- request lifecycle -----------------------------------------------------------
    def start(self, prompt: Sequence[int], doc: Sequence[int] = ()) -> None:
        self.tokens = list(prompt)
        self.n_prompt = len(prompt)
        self.doc = tuple(doc)
        self._prefill()

    def _prefill(self) -> None:
        t0 = time.perf_counter()
        seq = list(self.doc) + self.tokens
        toks = jnp.asarray(np.asarray(seq, np.int32))[None]
        last, state, pos = self._prefill_jit(self.params, toks)
        self._last_logits = last
        self._state = state
        self._pos = pos
        jax.block_until_ready(last)
        self.stats.prefill_time += time.perf_counter() - t0
        self.stats.prefills += 1

    def set_doc(self, doc: Sequence[int]) -> None:
        """Prepend-replace the retrieved chunk (re-prefill if it changed)."""
        doc = tuple(doc)
        if doc == self.doc:
            return
        self.doc = doc
        self._prefill()

    # ---- generation -------------------------------------------------------------------
    def gen(self, k: int) -> List[int]:
        """Greedy-decode up to k tokens (stops at EOS). Returns the new tokens."""
        t0 = time.perf_counter()
        out = []
        logits = self._last_logits
        for _ in range(k):
            tok = int(jnp.argmax(logits[0]))
            out.append(tok)
            self.tokens.append(tok)
            if tok == self.eos_id:
                break
            logits, self._state = self._decode_jit(
                self.params, self._state, jnp.asarray([tok], jnp.int32), self._pos)
            self._pos = self._pos + 1
            self._last_logits = logits
        jax.block_until_ready(self._last_logits)
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decodes += len(out)
        return out

    def peek_logits(self) -> np.ndarray:
        """Logits for the *next* token given the current context (KNN-LM interp)."""
        return np.asarray(self._last_logits[0])

    def advance(self, tok: int) -> None:
        """Append an externally-chosen token (KNN-LM: interpolated argmax)."""
        t0 = time.perf_counter()
        self.tokens.append(int(tok))
        logits, self._state = self._decode_jit(
            self.params, self._state, jnp.asarray([int(tok)], jnp.int32), self._pos)
        self._pos = self._pos + 1
        self._last_logits = logits
        jax.block_until_ready(logits)
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decodes += 1

    @property
    def generated(self) -> List[int]:
        return self.tokens[self.n_prompt:]

    @property
    def finished(self) -> bool:
        return bool(self.generated) and self.generated[-1] == self.eos_id

    # ---- speculation support ------------------------------------------------------------
    def snapshot(self):
        """O(1): JAX arrays are immutable, so references suffice (DESIGN §5 — this is
        what makes rollback exact for recurrent/SSM archs, not just KV models)."""
        return (len(self.tokens), self.doc, self._state, self._pos, self._last_logits)

    def restore(self, snap) -> None:
        n, doc, state, pos, last = snap
        self.tokens = self.tokens[:n]
        self.doc = doc
        self._state = state
        self._pos = pos
        self._last_logits = last
