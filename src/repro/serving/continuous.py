"""ContinuousFleetServer: continuous batching for RaLMSpec fleet serving.

FleetServer serves fixed groups of N requests in lockstep, so once fast
requests finish, their slots idle until the whole group drains — per-request
cost climbs back toward the sequential baseline exactly when the fleet should
be amortizing hardest. Continuous batching removes that waste: the server owns
a request queue and a pool of engine slots, admits waiting requests into slots
the moment they free up mid-flight (per-slot prefill into the live batch, via
BatchedServeEngine.admit), and retires finished slots immediately. The round
loop is FleetServer._run_round over whatever slot set is live *this* round, so
every live slot's verification queries still merge into ONE batched KB call
per round (§A.1 cross-request batched verification) no matter how the slot
population churns — and, like every KB call in the repo, that merged call
executes on whichever retrieval backend the retriever was built with (flat /
kernel / sharded-mesh; one collective per call for the latter).

Timeline: the server advances a MODELED clock (the paper's §A.1
batched-retrieval latency shape for KB calls + measured wall time for the
batched LM work, same convention as FleetServer.analytic_time). Request
arrivals are points on that clock — Poisson or trace-driven, see
repro.launch.serve --arrival-rate / --arrival-trace — and admission happens
when ``arrival <= clock`` and a slot is free, so queueing delay is part of
each request's reported latency. Wall-clock totals are reported alongside, as
everywhere in this repo.

Output preservation holds under churn: each request's tokens are byte-identical
to single-request RaLMSeq regardless of when it was admitted, which slot it
landed in (including reused slots), or what rollbacks its slot neighbors took —
tests/test_continuous.py asserts this for EDR/ADR/SR under staggered
admissions, slot reuse, and randomized arrival orders.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.ralmspec import ServeResult
from repro.serving.fleet import FleetServer


@dataclass
class Request:
    """One queued serving request on the modeled timeline."""

    rid: int
    prompt: Sequence[int]
    arrival: float = 0.0               # modeled arrival time (seconds)
    max_new: Optional[int] = None      # per-request budget; None -> rcfg's


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in 0..100) — the one definition shared by
    ContinuousResult and the scheduler benchmarks, so p50/p99 comparisons
    across schedulers can never diverge on rounding."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(len(ys) - 1, max(0, round(q / 100.0 * (len(ys) - 1))))]


def as_requests(prompts: Sequence[Sequence[int]],
                arrivals: Optional[Sequence[float]] = None,
                max_new: Optional[Sequence[int]] = None) -> List[Request]:
    """Zip plain prompt lists into Request records (rid = position)."""
    return [Request(rid=i, prompt=p,
                    arrival=float(arrivals[i]) if arrivals is not None else 0.0,
                    max_new=max_new[i] if max_new is not None else None)
            for i, p in enumerate(prompts)]


@dataclass
class ContinuousResult:
    """Per-request ledgers (request order) plus the shared fleet timeline."""

    results: List[ServeResult] = field(default_factory=list)
    latencies: List[float] = field(default_factory=list)  # modeled finish-arrival
    wall_time: float = 0.0
    analytic_time: float = 0.0         # modeled makespan (clock at last retire)
    rounds: int = 0
    seed_calls: int = 0                # batched admission-seed KB calls
    kb_calls: int = 0
    kb_queries: int = 0
    max_live: int = 0                  # peak concurrently-live slots
    # in-round verification dedup ledger (same semantics as FleetResult)
    merged_rows: int = 0
    merged_rows_saved: int = 0
    # fault-tolerance ledger (same semantics as FleetResult), plus the
    # overload-shedding count: requests retired with status='shed' by the
    # bounded admission queue / queueing deadline before winning a slot
    kb_errors: int = 0
    kb_timeouts: int = 0
    kb_failures: int = 0
    seed_failures: int = 0
    degraded_rounds: int = 0
    worker_crashes: int = 0
    shed: int = 0

    @property
    def degraded_requests(self) -> int:
        return sum(1 for r in self.results if r.status == "degraded")

    @property
    def total_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.results)

    def throughput(self, modeled: bool = True) -> float:
        """Aggregate tokens/s over the makespan (modeled timeline by default —
        the paper-hardware batched-retrieval shape; wall on this box)."""
        t = self.analytic_time if modeled else self.wall_time
        return self.total_tokens / max(t, 1e-9)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0..100) of modeled per-request latency — queueing
        delay included, which is the point of measuring under an arrival rate."""
        return percentile(self.latencies, q)

    @property
    def p50(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99)


class ContinuousFleetServer(FleetServer):
    """Queue + slot pool: admit mid-flight, retire on finish, one merged KB
    verification call per round over whichever slots are live.

    Admission seeding (Algorithm 1 line 4) rides along existing KB calls
    whenever it can: each round's merged verification call also carries seed
    queries for the arrived head of the queue (a queued request's seed query
    depends only on its prompt, so it can be computed before admission), and
    the pre-seeded ids are inserted into the request's fresh cache when it
    wins a slot — no separate KB call. A dedicated batched seed call (counted
    in ``ContinuousResult.seed_calls``) remains only for admission waves no
    verification call could have pre-seeded: the initial wave, waves after
    the pool drains idle, and requests that arrived after the last round's
    call was already issued.

    Async (pipelined) rounds compose with churn: the in-flight verification
    call lives entirely inside ``_run_round`` (submitted after stage-1
    speculation, joined before the per-slot split), so the slot population
    only ever mutates between rounds — ``_drain_inflight`` guards the
    admission and retirement paths against any future caller mutating slots
    while a call is still pending. Requests that arrive while the call is in
    flight ride it for pre-seeding (``_extra_verification_queries`` attaches
    their seed queries at submission time) and are admitted right after the
    join. A slot holding an unverified overlapped stride (a pending
    ``RequestState.carry``) cannot retire until the carry is verified —
    otherwise a final-stride mis-speculation would escape its correction."""

    def serve(self, requests: Sequence[Request]) -> ContinuousResult:
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        reqs = sorted(requests, key=lambda rq: (rq.arrival, rq.rid))
        queue = deque(reqs)
        eng.stats.reset()
        for b in range(eng.n_slots):        # a fresh serve() owns every slot
            if eng.active[b]:
                eng.retire(b)
        r0t = r.stats.time
        r0c, r0q = r.stats.calls, r.stats.queries
        m0, ms0 = self.merged_rows, self.merged_rows_saved
        r0e, r0o, r0f = r.stats.errors, r.stats.timeouts, r.stats.failed_calls
        sf0 = self.seed_failures
        out = ContinuousResult()
        states = {}                         # slot -> RequestState (live only)
        done = {}                           # rid  -> RequestState (retired)
        self._queue = queue
        self._preseed = {}                  # rid -> prefetched (ids, scores) rows
        self._extra_rids = []
        self._clock = clock = 0.0
        t0 = time.perf_counter()

        while queue or states:
            if not states and queue:        # pool drained: jump to next arrival
                clock = max(clock, queue[0].arrival)

            # ---- load shedding: graceful degradation under overload --------
            self._shed_overloaded(queue, done, out, clock, t0)

            # ---- admit: arrived requests into free slots, mid-flight -------
            # the slot population must never mutate under an in-flight
            # verification call (its query offsets index the pre-admission
            # participant list) — join it first; a no-op in the current
            # design, where _run_round drains its own call before returning
            self._drain_inflight()
            unseeded = []
            free = eng.free_slots()
            while queue and free and queue[0].arrival <= clock:
                rq = queue.popleft()
                b = free.pop(0)
                st = self._new_request_state(rid=rq.rid, max_new=rq.max_new)
                st.arrival, st.admitted = rq.arrival, clock
                eng.admit(b, list(rq.prompt)[-rcfg.max_prompt_len:])
                states[b] = st
                if rq.rid in self._preseed:  # seeded by an earlier round's call
                    self.workload.seed_from_merged(self, st,
                                                   *self._preseed.pop(rq.rid))
                    st.res.kb_calls += 1
                    st.res.kb_queries += 1
                else:
                    unseeded.append((b, st))
            if unseeded:
                # Algorithm 1 line 4, batched across the admission wave: ONE
                # KB call seeds every newly admitted un-preseeded slot's cache
                clock += self._seed_slots(unseeded)
                out.seed_calls += 1
            out.max_live = max(out.max_live, len(states))

            # ---- one speculation round over the currently live slot set ----
            # slots with a pending carry hold an UNVERIFIED overlapped stride:
            # they stay live past budget/EOS until it is verified (same rule
            # as FleetServer.serve and the single-request loop)
            live = [b for b in sorted(states)
                    if not self._slot_done(b, states[b]) or states[b].carry]
            if live:
                self._clock = clock
                a, _ = self._run_round(live, states, out)
                clock += a

            # ---- retire finished slots (frees them for the next admit) -----
            self._drain_inflight()
            for b in sorted(states):
                st = states[b]
                if self._slot_done(b, st) and not st.carry:
                    st.finished = clock
                    st.res.tokens = list(eng.generated(b))
                    st.res.analytic_time = clock - st.arrival
                    st.res.wall_time = time.perf_counter() - t0
                    done[st.rid] = st
                    eng.retire(b)
                    del states[b]

        out.wall_time = time.perf_counter() - t0
        out.analytic_time = clock
        out.kb_calls = r.stats.calls - r0c
        out.kb_queries = r.stats.queries - r0q
        out.merged_rows = self.merged_rows - m0
        out.merged_rows_saved = self.merged_rows_saved - ms0
        out.kb_errors = r.stats.errors - r0e
        out.kb_timeouts = r.stats.timeouts - r0o
        out.kb_failures = r.stats.failed_calls - r0f
        out.seed_failures = self.seed_failures - sf0
        # report in request order; gen/retrieval time are fleet-shared (the
        # batched engine pays them once), same convention as FleetServer.
        # Shed requests keep their result row (status='shed', no tokens) but
        # stay OUT of the latency distribution — p50/p99 describe service the
        # fleet actually delivered, shedding is its own counter.
        for rq in sorted(reqs, key=lambda x: x.rid):
            st = done[rq.rid]
            st.res.gen_time = eng.stats.gen_time
            st.res.retrieval_time = r.stats.time - r0t
            out.results.append(st.res)
            if st.res.status != "shed":
                out.latencies.append(st.finished - st.arrival)
        return out

    def _shed_overloaded(self, queue, done, out, clock: float,
                         t0: float) -> None:
        """Bounded admission + deadline-driven load shedding (ROADMAP item 4):
        retire waiting requests the fleet cannot serve in time with a ``shed``
        status instead of queueing unboundedly. ``rcfg.queue_deadline_s``
        sheds any ARRIVED request whose queueing delay already exceeds the
        deadline; ``rcfg.max_queue_depth`` then bounds how many arrived
        requests may keep waiting — newest arrivals are turned away first,
        the bounded-queue admission policy. Requests not yet arrived on the
        modeled clock are never considered (they haven't been offered)."""
        rcfg = self.rcfg
        if not (rcfg.max_queue_depth or rcfg.queue_deadline_s):
            return
        arrived = [rq for rq in queue if rq.arrival <= clock]
        drop = [rq for rq in arrived
                if rcfg.queue_deadline_s
                and clock - rq.arrival > rcfg.queue_deadline_s]
        if rcfg.max_queue_depth:
            waiting = [rq for rq in arrived if rq not in drop]
            # the head of the line is about to be admitted into free slots —
            # the depth bound applies to requests that actually keep waiting
            waiting = waiting[len(self.engine.free_slots()):]
            drop += waiting[rcfg.max_queue_depth:]
        for rq in drop:
            queue.remove(rq)
            st = self._new_request_state(rid=rq.rid, max_new=rq.max_new)
            st.arrival, st.finished = rq.arrival, clock
            st.res.status = "shed"
            st.res.analytic_time = clock - rq.arrival
            st.res.wall_time = time.perf_counter() - t0
            done[rq.rid] = st
            out.shed += 1

    # ---- seed-query ride-along (see class docstring) ------------------------
    def _extra_verification_queries(self, spec_elapsed: float):
        # the verification call is issued spec_elapsed past the round-start
        # clock, so requests that arrived during the speculation phase ride it
        issue_time = self._clock + spec_elapsed
        qs, self._extra_rids = [], []
        for rq in self._queue:
            if len(qs) >= self.engine.n_slots:
                break
            if rq.arrival <= issue_time and rq.rid not in self._preseed:
                qs.append(self._query_tokens(
                    list(rq.prompt)[-self.rcfg.max_prompt_len:]))
                self._extra_rids.append(rq.rid)
        return qs

    def _absorb_extra_verification(self, ids_rows, sc_rows) -> None:
        for rid, row, srow in zip(self._extra_rids, ids_rows, sc_rows):
            self._preseed[rid] = (row, srow)
        self._extra_rids = []
