"""Batched multi-request serving engine: one batch-dim decode over N slots.

``BatchedServeEngine`` generalizes :class:`repro.serving.engine.ServeEngine` from
one request to ``n_slots`` concurrent requests while keeping its exactness
contract: every slot's token stream is *identical* to what a single-request
engine would produce for the same prompt/doc schedule
(tests/test_output_preservation.py asserts this token-for-token).

Design (ROADMAP north star: fleet-level amortization):
  * one batched decode state (leading batch dim over slots). A lockstep decode
    step advances every *live* slot with a single jitted ``Model.decode_step``
    call at per-slot absolute positions — the G-cost of a speculation stride is
    paid once per fleet, not once per request.
  * per-slot prefill: slot contexts differ in length, so prefill stays per-slot
    (re-prefill on doc swap is the Ram-et-al. baseline semantics) and the
    resulting row is scattered into the batched state. Prefill shapes live on
    the same fixed grid as the single engine, so the jit cache is shared.
  * per-slot snapshot/restore: JAX arrays are immutable, so a snapshot is an
    O(1) reference to the whole batched pytree plus the slot's scalars; restore
    writes back only that slot's row. Mis-speculation rollback in one slot
    therefore cannot perturb sibling slots (regression-tested in
    tests/test_output_preservation.py). This row-granular semantics is what
    makes async fleet rounds' overlapped strides revocable: a snapshot taken
    before an overlapped step can be restored a ROUND later — after siblings
    advanced, rolled back, or (continuous batching) retired and readmitted —
    and still rewinds exactly one slot to exactly that step
    (tests/test_async_fleet.py).
  * slots leave a lockstep ``gen`` when they hit EOS or their own budget; a
    masked merge commits each slot's state as of its *own* last step, so late
    leavers keep decoding batched while early leavers stay frozen.
  * slot lifecycle (continuous batching): ``admit(slot, prompt)`` prefills a
    request into a free slot of the LIVE batch — the scatter touches only that
    slot's row, so sibling slots' caches/positions are undisturbed — and
    ``retire(slot)`` frees it again. ``gen``/``snapshot``/``restore`` operate
    only on active slots (active-slot masking); a retired slot's device row
    stays stale until the next admit prefills over it.
    ContinuousFleetServer (repro.serving.continuous) drives this API to admit
    queued requests mid-flight the moment slots free up.

The engine is cache-agnostic: the fleet servers attach Algorithm-1 state
(including each slot's speculation cache — a plain per-request cache, or a
``SharedCacheView`` over the fleet-wide ``SharedRetrievalCache`` tier when the
shared tier is enabled) per slot via ``RequestState``; nothing here reads it.
The exactness contract above is exactly why the shared tier preserves outputs:
speculation picks the docs, but this engine replays whatever verification
confirms, token-for-token.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.engine import EngineStats


def _row_mask(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


class BatchedServeEngine:
    """N-slot greedy engine over a Model: batched decode, per-slot lifecycle."""

    def __init__(self, model: Model, params, n_slots: int, *,
                 cache_window: int = 2048, eos_id: int = -1,
                 extra: Optional[dict] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.W = cache_window
        self.eos_id = eos_id
        self.extra = extra
        self.stats = EngineStats()
        self._decode_jit = jax.jit(
            lambda p, st, tok, pos: model.decode_step(p, st, tok, pos))
        self._prefill_jit = jax.jit(
            lambda p, toks: model.prefill(p, toks, extra=extra,
                                          window_cache=self.W))
        # scatter one prefilled row into the batched bundle / restore one row
        # from a snapshot bundle / commit rows by mask — all jitted once, with a
        # traced slot index so no per-slot recompiles
        self._scatter_jit = jax.jit(lambda cur, row, b: jax.tree.map(
            lambda c, r: c.at[b].set(r[0]), cur, row))
        self._restore_jit = jax.jit(lambda cur, old, b: jax.tree.map(
            lambda c, o: c.at[b].set(o[b]), cur, old))
        self._commit_jit = jax.jit(lambda new, com, mask: jax.tree.map(
            lambda n, c: jnp.where(_row_mask(mask, n), n, c), new, com))
        # per-slot bookkeeping (host side)
        self.tokens: List[List[int]] = [[] for _ in range(n_slots)]
        self.n_prompt = [0] * n_slots
        self.doc: List[Tuple[int, ...]] = [()] * n_slots
        self.active = [False] * n_slots
        # batched device state: (decode state, per-slot positions, last logits)
        self._state = model.init_decode_state(n_slots, self.W)
        self._pos = jnp.zeros((n_slots,), jnp.int32)
        self._last_logits = jnp.zeros((n_slots, model.cfg.vocab_size), jnp.float32)

    # ---- bundle helpers ---------------------------------------------------------------
    def _bundle(self):
        return (self._state, self._pos, self._last_logits)

    def _set_bundle(self, bundle) -> None:
        self._state, self._pos, self._last_logits = bundle

    def warm(self, lengths: Sequence[int]) -> None:
        """Precompile the prefill shape grid plus one batched decode step."""
        for L in sorted(set(int(x) for x in lengths)):
            toks = jnp.zeros((1, L), jnp.int32)
            last, state, pos = self._prefill_jit(self.params, toks)
            jax.block_until_ready(last)
        logits, _ = self._decode_jit(self.params, self._state,
                                     jnp.zeros((self.n_slots,), jnp.int32),
                                     self._pos)
        jax.block_until_ready(logits)

    # ---- slot lifecycle ---------------------------------------------------------------
    def admit(self, slot: int, prompt: Sequence[int],
              doc: Sequence[int] = ()) -> None:
        """Admit a request into a FREE slot of the live batch. The per-slot
        prefill scatters only row ``slot`` of the batched state, so sibling
        slots keep decoding from exactly where they were — this is what lets
        continuous batching admit mid-flight (even between a sibling's
        snapshot and its rollback restore; tests/test_continuous.py)."""
        assert not self.active[slot], f"admit into busy slot {slot}"
        self.active[slot] = True
        self.tokens[slot] = list(prompt)
        self.n_prompt[slot] = len(prompt)
        self.doc[slot] = tuple(doc)
        self._prefill_slot(slot)

    def retire(self, slot: int) -> None:
        """Free a finished slot. Host bookkeeping is cleared immediately; the
        slot's device row is left stale on purpose (the next admit's prefill
        overwrites it), so retirement costs nothing on device."""
        assert self.active[slot], f"retire of idle slot {slot}"
        self.active[slot] = False
        self.tokens[slot] = []
        self.n_prompt[slot] = 0
        self.doc[slot] = ()

    def free_slots(self) -> List[int]:
        return [b for b in range(self.n_slots) if not self.active[b]]

    def start(self, slot: int, prompt: Sequence[int],
              doc: Sequence[int] = ()) -> None:
        """Fixed-group entry point: (re)start a slot — retire-if-busy + admit."""
        if self.active[slot]:
            self.retire(slot)
        self.admit(slot, prompt, doc)

    def _prefill_slot(self, slot: int) -> None:
        t0 = time.perf_counter()
        seq = list(self.doc[slot]) + self.tokens[slot]
        toks = jnp.asarray(np.asarray(seq, np.int32))[None]
        last, state, pos = self._prefill_jit(self.params, toks)
        b = jnp.int32(slot)
        self._state = self._scatter_jit(self._state, state, b)
        self._pos = self._pos.at[slot].set(pos)
        self._last_logits = self._last_logits.at[slot].set(last[0])
        jax.block_until_ready(self._last_logits)
        self.stats.prefill_time += time.perf_counter() - t0
        self.stats.prefills += 1

    def set_doc(self, slot: int, doc: Sequence[int]) -> None:
        """Prepend-replace the slot's retrieved chunk (re-prefill if changed)."""
        doc = tuple(doc)
        if doc == self.doc[slot]:
            return
        self.doc[slot] = doc
        self._prefill_slot(slot)

    # ---- generation -------------------------------------------------------------------
    def gen(self, slots: Sequence[int], ks: Sequence[int]) -> List[List[int]]:
        """Lockstep greedy decode: up to ``ks[i]`` tokens for ``slots[i]`` (each
        slot stops at EOS or its own budget). One batched decode per step.
        Returns the new tokens per requested slot."""
        assert all(self.active[int(b)] for b in slots), \
            f"gen over idle slot(s): {[int(b) for b in slots if not self.active[int(b)]]}"
        t0 = time.perf_counter()
        remaining = {int(b): int(k) for b, k in zip(slots, ks)}
        out = {int(b): [] for b in slots}
        live = [b for b, k in remaining.items() if k > 0]
        committed = self._bundle()
        current = committed
        while live:
            state, pos, logits = current
            next_tok = np.asarray(jnp.argmax(logits, axis=-1))
            eos_exits, budget_exits = [], []
            tok_vec = np.zeros((self.n_slots,), np.int32)
            for b in live:
                t = int(next_tok[b])
                out[b].append(t)
                self.tokens[b].append(t)
                if t == self.eos_id:
                    eos_exits.append(b)     # EOS: no decode for this token
                    continue
                tok_vec[b] = t
                remaining[b] -= 1
                if remaining[b] <= 0:
                    budget_exits.append(b)  # budget: commit *after* this decode
            if eos_exits:
                committed = self._commit_bundle(current, committed, eos_exits)
                live = [b for b in live if b not in eos_exits]
                if not live:
                    break
            logits2, state2 = self._decode_jit(
                self.params, state, jnp.asarray(tok_vec), pos)
            live_mask = np.zeros((self.n_slots,), bool)
            live_mask[live] = True
            pos2 = pos + jnp.asarray(live_mask, jnp.int32)
            current = (state2, pos2, logits2)
            if budget_exits:
                committed = self._commit_bundle(current, committed, budget_exits)
                live = [b for b in live if b not in budget_exits]
        self._set_bundle(committed)
        jax.block_until_ready(self._last_logits)
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decodes += sum(len(v) for v in out.values())
        return [out[int(b)] for b in slots]

    def _commit_bundle(self, current, committed, slot_list):
        mask = np.zeros((self.n_slots,), bool)
        mask[slot_list] = True
        return self._commit_jit(current, committed, jnp.asarray(mask))

    def peek_logits(self, slot: int) -> np.ndarray:
        """Logits for the slot's *next* token given its current context —
        the batched form of ServeEngine.peek_logits (KNN-LM interpolation)."""
        assert self.active[slot], f"peek_logits of idle slot {slot}"
        return np.asarray(self._last_logits[slot])

    def advance(self, slots: Sequence[int], toks: Sequence[int]) -> None:
        """Append one externally-chosen token per given slot (KNN-LM: the
        interpolated argmax) and run ONE batched decode step over exactly
        those slots — the lockstep form of ServeEngine.advance, and the
        KNN-LM fleet's whole G-cost per speculation sub-step. Non-participant
        slots' rows are decoded with a dummy token and discarded by the
        masked commit, exactly as in ``gen``, so their state is untouched."""
        slots = [int(b) for b in slots]
        assert all(self.active[b] for b in slots), \
            f"advance over idle slot(s): {[b for b in slots if not self.active[b]]}"
        t0 = time.perf_counter()
        state, pos, logits = self._bundle()
        tok_vec = np.zeros((self.n_slots,), np.int32)
        for b, t in zip(slots, toks):
            t = int(t)
            self.tokens[b].append(t)
            tok_vec[b] = t
        logits2, state2 = self._decode_jit(self.params, state,
                                           jnp.asarray(tok_vec), pos)
        mask = np.zeros((self.n_slots,), bool)
        mask[slots] = True
        pos2 = pos + jnp.asarray(mask, jnp.int32)
        self._set_bundle(self._commit_bundle((state2, pos2, logits2),
                                             self._bundle(), slots))
        jax.block_until_ready(self._last_logits)
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decodes += len(slots)

    # ---- per-slot views ---------------------------------------------------------------
    def generated(self, slot: int) -> List[int]:
        return self.tokens[slot][self.n_prompt[slot]:]

    def finished(self, slot: int) -> bool:
        g = self.generated(slot)
        return bool(g) and g[-1] == self.eos_id

    # ---- speculation support ------------------------------------------------------------
    def snapshot(self, slot: int):
        """O(1): references to the immutable batched bundle + the slot's scalars.
        The bundle's row `slot` is the slot's state at snapshot time; sibling
        rows are ignored on restore — which is why a snapshot stays valid
        across round boundaries (async overlapped strides) no matter what
        siblings did in between."""
        assert self.active[slot], f"snapshot of idle slot {slot}"
        return (len(self.tokens[slot]), self.doc[slot], self._bundle())

    def restore(self, slot: int, snap) -> None:
        """Rewind ``slot`` to a snapshot it took earlier in ITS OWN request
        (any number of gen/set_doc/sibling-ops later, including overlapped
        strides from async fleet rounds). The slot's token list must be an
        extension of the snapshotted one — restoring across a retire/admit
        would silently decode from another request's state, so fail loudly."""
        assert self.active[slot], f"restore of idle slot {slot}"
        n, doc, bundle = snap
        assert n <= len(self.tokens[slot]), \
            f"slot {slot}: snapshot is not from this request's lineage"
        self.tokens[slot] = self.tokens[slot][:n]
        self.doc[slot] = doc
        b = jnp.int32(slot)
        self._set_bundle(self._restore_jit(self._bundle(), bundle, b))
