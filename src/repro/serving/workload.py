"""Workload interface: the Algorithm-1 specifics of a fleet speculation round.

``FleetServer._run_round`` is a workload-GENERIC two-stage pipeline — lockstep
speculation sub-steps, ONE merged verification KB call (dedup'd, shared-cache
published, fault-guarded, optionally overlapped on the async worker), then a
per-slot split with rollback/carry — but WHAT a speculation sub-step does,
what the merged rows mean, and what "the speculation was right" means are
workload properties. This module is that seam:

  * :class:`IterativeRaLMWorkload` — the paper's iterative RaLM (Algorithm 1):
    a sub-step speculates a document from the cache (top-1), prepend-replaces
    it (re-prefill), and generates a stride; verification compares speculated
    DOC IDS against the KB top-1 (byte-parity equivalence, ``equivalence ==
    'byte'``); the cache-update rule inserts the verified top-k rows.
  * :class:`KNNLMWorkload` — KNN-LM serving (paper §5.3): every sub-step is
    one token — retrieve k neighbours from the cache, interpolate their value
    distribution with the LM logits (:func:`~repro.core.knnlm.knn_interpolate`),
    and advance the batched engine one step; verification recomputes the
    token from the KB's ground-truth neighbours and the RECORDED logits
    (token-match equivalence, ``equivalence == 'token-match'`` — matching the
    decoded token is sufficient for output preservation, matching all k
    neighbour sets would be exponentially unlikely); the cache-update rule is
    the spatial-locality next-n insert (consecutive datastore entries are
    consecutive training positions).

Both workloads flow through the SAME merged KB call, shared cache tier, dedup
ledger, ``_retrieve_guarded`` fault shell, and async overlap machinery —
nothing in ``serving/fleet.py`` or ``serving/continuous.py`` branches on the
workload beyond these hooks. Workload instances are stateless (every hook
takes the server as its first argument), so one instance can serve any number
of servers.

Per-step auxiliary state rides :attr:`repro.core.ralmspec.RequestState.aux`
(and the 5th element of async carry tuples): iterative RaLM records ``None``;
KNN-LM records the LM logits captured at speculation time, which is exactly
what makes overlapped (carried) KNN-LM steps verifiable a round later.
"""
from __future__ import annotations

import time
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.configs.base import RaLMConfig
from repro.core.knnlm import knn_interpolate
from repro.core.ralmspec import first_mismatch


class Workload:
    """Strategy object for the fleet round loop's workload-specific steps.

    ``equivalence`` names the output-preservation contract the workload's
    verification enforces per slot: ``'byte'`` (outputs byte-identical to the
    sequential baseline) or ``'token-match'`` (KNN-LM's relaxed rule — the
    decoded token stream matches the baseline's, which is what "output" means
    for a language model, without requiring identical neighbour sets)."""

    name: str = "?"
    equivalence: str = "byte"

    def validate(self, srv) -> None:
        """Raise ValueError if the server's retriever/KB cannot run this
        workload (called once at server construction)."""

    def verify_k(self, rcfg: RaLMConfig) -> int:
        """Rows per query in the merged verification/seed KB call."""
        raise NotImplementedError

    def speculate_step(self, srv, doers: Sequence[int], states) -> Tuple[Dict, float]:
        """One lockstep speculation sub-step over ``doers``. Returns
        ``({slot: (snap, query, spec, aux)}, wall_seconds)`` where ``spec``
        is whatever verification will check (a doc id, a token) and ``aux``
        is the workload's per-step record (None if it needs none)."""
        raise NotImplementedError

    def build_verification_queries(self, st) -> Sequence:
        """The slot's contribution to the round's merged verification KB call
        — by default the queries its speculation sub-steps recorded, in step
        order (both workloads verify exactly what they speculated from)."""
        return st.queries

    def check_and_commit(self, srv, st, gt_ids, gt_scores) -> Tuple[int, object]:
        """Apply the workload's cache-update rule for the slot's verified
        rows and locate the first mis-speculated step. Returns
        ``(m, correction)``: ``m == len(st.specs)`` means the whole stride
        verified (correction is None); otherwise ``correction`` is the
        payload ``apply_correction``/``correction_stride`` need to replay
        step ``m`` correctly after the rollback restore."""
        raise NotImplementedError

    def seed_from_merged(self, srv, st, ids_row, scores_row) -> None:
        """Admission-time cache warm from one merged-call row (Algorithm 1
        line 4 / the continuous ride-along pre-seed)."""
        raise NotImplementedError

    def apply_correction(self, srv, slot: int, st, correction) -> None:
        """Per-slot fixup right after the rollback restore (before the
        batched correction stride)."""

    def correction_stride(self, srv, slots: Sequence[int], states,
                          corrections: Dict[int, object]) -> None:
        """ONE batched engine call correcting every rolled-back slot."""
        raise NotImplementedError


class IterativeRaLMWorkload(Workload):
    """The paper's Algorithm 1, byte-identical to the pre-workload fleet."""

    name = "ralm"
    equivalence = "byte"

    def verify_k(self, rcfg: RaLMConfig) -> int:
        return max(rcfg.prefetch_top_k, 1)

    def speculate_step(self, srv, doers, states):
        """Per-slot snapshot + cache-speculated doc swap, then ONE batched
        generation stride. A spec_id of -1 (cold cache) keeps the slot's
        previous doc; verification will correct — same as the single path."""
        eng, rcfg = srv.engine, srv.rcfg
        t_sub = time.perf_counter()
        steps = {}
        for b in doers:
            snap = eng.snapshot(b)
            q = srv._query_tokens(eng.tokens[b])
            ids, _ = states[b].cache.retrieve(q, 1)
            did = int(ids[0])
            if did >= 0:
                eng.set_doc(b, srv._doc(did))
            steps[b] = (snap, q, did, None)
        eng.gen(doers, [min(rcfg.generation_stride,
                            srv._slot_budget(b, states[b]))
                        for b in doers])
        return steps, time.perf_counter() - t_sub

    def check_and_commit(self, srv, st, gt_ids, gt_scores):
        k = self.verify_k(srv.rcfg)
        for row in gt_ids:
            srv._cache_insert(st.cache, row[:k])
        m = first_mismatch(st.specs, gt_ids)
        corr = int(gt_ids[m][0]) if m < len(st.specs) else None
        return m, corr

    def seed_from_merged(self, srv, st, ids_row, scores_row):
        srv._cache_insert(st.cache, ids_row)

    def apply_correction(self, srv, slot, st, correction):
        srv.engine.set_doc(slot, srv._doc(correction))

    def correction_stride(self, srv, slots, states, corrections):
        srv.engine.gen(slots, [min(srv.rcfg.generation_stride,
                                   srv._slot_budget(b, states[b]))
                               for b in slots])


class KNNLMWorkload(Workload):
    """KNN-LM through the fleet (paper §5.3): per-token retrieval,
    spatial-locality cache updates, token-match verification."""

    name = "knnlm"
    equivalence = "token-match"

    def validate(self, srv) -> None:
        if srv.sparse:
            raise ValueError(
                "KNN-LM serving needs a dense datastore retriever "
                "(ExactDenseRetriever/IVFRetriever over build_knn_datastore); "
                "got a sparse BM25 retriever")
        if getattr(srv.retriever.kb, "values", None) is None:
            raise ValueError(
                "KNN-LM serving needs a value-carrying datastore "
                "(DenseKB from build_knn_datastore); got a KB without "
                "per-entry values")

    def verify_k(self, rcfg: RaLMConfig) -> int:
        return max(rcfg.knn_k, 1)

    def speculate_step(self, srv, doers, states):
        """One TOKEN per sub-step and per slot: retrieve ``knn_k`` neighbours
        from the slot's speculation cache, interpolate their value
        distribution with the current LM logits, advance the batched engine
        ONE lockstep step with the chosen tokens. The logits are recorded as
        the step's aux — verification recomputes the token from them plus the
        KB's ground-truth neighbours, so a carried (overlapped) step stays
        verifiable a round later. Cold-cache slots interpolate against an
        empty neighbour mass (pure LM argmax scaled by 1-λ … which argmax
        ignores), exactly like the single-request KNNLMSpec."""
        eng, rcfg = srv.engine, srv.rcfg
        kb = srv.retriever.kb
        t_sub = time.perf_counter()
        steps, toks = {}, []
        for b in doers:
            snap = eng.snapshot(b)
            q = srv._query_tokens(eng.tokens[b])
            ids, sc = states[b].cache.retrieve(q, rcfg.knn_k)
            vals = np.where(ids >= 0, kb.values[np.maximum(ids, 0)], -1)
            logits = eng.peek_logits(b)
            tok = knn_interpolate(logits, vals, sc, rcfg.knn_lambda)
            steps[b] = (snap, q, int(tok), logits)
            toks.append(int(tok))
        eng.advance(doers, toks)
        return steps, time.perf_counter() - t_sub

    def check_and_commit(self, srv, st, gt_ids, gt_scores):
        """Token-match verification (paper §5.3): step i is correct iff the
        token decoded from (recorded LM logits, KB ground-truth neighbours)
        equals the speculated token. By induction over matching prefixes the
        recorded logits equal what the sequential baseline saw, so the
        recomputed token IS the baseline's token — which is why the whole
        fleet stream token-matches KNNLMSeq. The cache-update rule is the
        spatial next-n insert for EVERY verified row (hit or miss)."""
        rcfg, kb = srv.rcfg, srv.retriever.kb
        n = len(st.specs)
        m, corr = n, None
        for i in range(n):
            gt_tok = knn_interpolate(st.aux[i], kb.values[gt_ids[i]],
                                     gt_scores[i], rcfg.knn_lambda)
            if gt_tok != int(st.specs[i]):
                m, corr = i, int(gt_tok)
                break
        for i in range(n):
            self._spatial_insert(srv, st.cache, gt_ids[i])
        return m, corr

    def seed_from_merged(self, srv, st, ids_row, scores_row):
        self._spatial_insert(srv, st.cache, ids_row)

    def correction_stride(self, srv, slots, states, corrections):
        """ONE batched advance replaying each rolled-back slot's ground-truth
        token (the single-request path's ``eng.advance(gt_correct)``)."""
        srv.engine.advance(slots, [corrections[b] for b in slots])

    def _spatial_insert(self, srv, cache, ids_row) -> None:
        """Paper §5.3 cache rule: insert the next-n entries *after* each
        retrieved datastore position (consecutive entries are consecutive
        training positions — spatial locality)."""
        kb, rcfg = srv.retriever.kb, srv.rcfg
        N = kb.size
        want = []
        for did in ids_row:
            did = int(did)
            if did < 0:
                continue
            want.extend(range(did, min(did + rcfg.knn_prefetch_next_n + 1, N)))
        want = [w for w in dict.fromkeys(want) if w not in cache]
        if want:
            cache.insert(want, kb.embeddings[want], kb.values[want])


def default_workload(rcfg: RaLMConfig) -> Workload:
    """The workload a server runs when not given one explicitly: keyed on
    ``rcfg.knnlm`` so existing call sites (tests, benchmarks) that build
    FleetServer directly keep working unchanged."""
    return KNNLMWorkload() if rcfg.knnlm else IterativeRaLMWorkload()
