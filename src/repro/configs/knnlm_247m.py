"""knnlm-247m — the paper's KNN-LM base model (Khandelwal et al. 2019).

16-layer decoder-only transformer, 247M trainable parameters (d_model=1024,
16 heads, d_ff=4096), used for the §5.3 KNN-LM serving experiments.
This is the paper's own model, included beyond the 10 assigned archs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="knnlm-247m",
    family="dense",
    num_layers=16,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=50304,
    source="arXiv:1911.00172",
)
