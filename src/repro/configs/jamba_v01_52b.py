"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536. Each period of 8 layers has
one attention layer (index 4, per the paper's figure); MoE replaces the FFN on every
second layer (moe_layer_rule="every_2").
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_PERIOD = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    moe_layer_rule="every_2",
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk=256),
    block_pattern=_PERIOD,
    source="arXiv:2403.19887",
)
