"""Config registry: ``get_config(arch_id)`` + ``reduced(config)`` for smoke tests."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AsyncConfig, FaultConfig, InputShape,
                                ModelConfig, MoEConfig, QueueConfig,
                                RaLMConfig, SpeculationConfig, SSMConfig)
from repro.configs.shapes import LONG_CONTEXT_WINDOW, SHAPES

from repro.configs import (  # noqa: E402
    command_r_plus_104b,
    jamba_v01_52b,
    kimi_k2_1t_a32b,
    knnlm_247m,
    llama32_1b,
    paligemma_3b,
    qwen15_110b,
    qwen2_moe_a27b,
    qwen3_4b,
    ralm_gpt2_medium,
    whisper_base,
    xlstm_350m,
)

_MODULES = (
    kimi_k2_1t_a32b,
    qwen15_110b,
    xlstm_350m,
    whisper_base,
    paligemma_3b,
    qwen2_moe_a27b,
    command_r_plus_104b,
    qwen3_4b,
    jamba_v01_52b,
    llama32_1b,
    knnlm_247m,
    ralm_gpt2_medium,
)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 architectures assigned from the public pool (the extra two are the paper's own).
ASSIGNED_ARCHS = (
    "kimi-k2-1t-a32b",
    "qwen1.5-110b",
    "xlstm-350m",
    "whisper-base",
    "paligemma-3b",
    "qwen2-moe-a2.7b",
    "command-r-plus-104b",
    "qwen3-4b",
    "jamba-v0.1-52b",
    "llama3.2-1b",
)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
            vocab: int = 512, experts: int = 4) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers, d_model<=512,
    <=4 experts), preserving every structural feature of the full config."""
    n_heads = max(2, min(cfg.num_heads, 4))
    n_kv = max(1, min(cfg.num_kv_heads, n_heads))
    head_dim = max(16, d_model // n_heads)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=(d_model * 4 if cfg.d_ff else 0),
        vocab_size=vocab,
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(experts, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            d_expert=d_model * 2,
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            dispatch_chunk=64,
        )
        # keep at least one MoE layer in 2-layer smoke models
        if cfg.moe_layer_rule in ("every_2", "dense_first"):
            updates["moe_layer_rule"] = cfg.moe_layer_rule
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, chunk=32)
        if cfg.ssm.kind == "xlstm":
            # keep both block kinds in the 2-layer smoke variant
            updates["block_pattern"] = ("mlstm", "slstm")[: layers]
    if cfg.block_pattern:
        # preserve the hybrid character within 2 layers: one mamba + one attn
        updates["block_pattern"] = ("mamba", "attn")[: layers]
    if cfg.encoder_layers:
        updates["encoder_layers"] = min(2, cfg.encoder_layers)
        updates["encoder_frames"] = 64
    if cfg.vision_patches:
        updates["vision_patches"] = 16
    return dataclasses.replace(cfg, **updates)


__all__ = [
    "ASSIGNED_ARCHS",
    "AsyncConfig",
    "FaultConfig",
    "InputShape",
    "LONG_CONTEXT_WINDOW",
    "ModelConfig",
    "MoEConfig",
    "QueueConfig",
    "RaLMConfig",
    "SpeculationConfig",
    "REGISTRY",
    "SHAPES",
    "SSMConfig",
    "get_config",
    "get_shape",
    "reduced",
]
