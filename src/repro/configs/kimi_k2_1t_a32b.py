"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384 experts top-8.
DeepSeek-V3-lineage layout: first layer dense FFN, remaining layers routed MoE with
one always-on shared expert.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,  # 7168 / 64
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048, num_shared_experts=1),
    moe_layer_rule="dense_first",
    source="arXiv:2501.kimi2",
)
