"""paligemma-3b — SigLIP + gemma VLM backbone, vision tower STUBBED [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216. input_specs()
provides pre-projected patch embeddings (256 patches) as the sequence prefix;
the gemma-style decoder (GeGLU-ish FFN approximated by SwiGLU, RoPE) is fully real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    vision_patches=256,
    tie_embeddings=True,
    source="arXiv:2407.07726",
)
