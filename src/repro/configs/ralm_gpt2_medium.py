"""ralm-gpt2-medium — the paper's smallest naive-iterative-RaLM host model.

GPT2-medium geometry (24L, d_model=1024, 16H, d_ff=4096, vocab=50257) expressed in
the same decoder stack as the rest of the zoo. Included beyond the 10 assigned archs
so the serving benchmarks exercise the paper's own model class.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ralm-gpt2-medium",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=50257,
    qkv_bias=True,
    source="gpt2-medium (Radford et al., 2019)",
)
