"""whisper-base — enc-dec audio backbone, conv frontend STUBBED [arXiv:2212.04356].

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865. Encoder consumes precomputed
frame embeddings (the mel+conv frontend is the assignment's allowed stub);
decoder is a standard transformer with cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,           # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_frames=1500,    # 30 s of audio at 50 Hz after conv stride-2
    rope_theta=0.0,         # whisper uses learned/sinusoidal positions, not RoPE
    source="arXiv:2212.04356",
)
