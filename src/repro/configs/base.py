"""Configuration system for repro.

Every assigned architecture is expressed as a :class:`ModelConfig`. The config is a
plain frozen dataclass so it can be hashed into jit static args and printed into
EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (None on dense archs)."""

    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    num_shared_experts: int = 0    # always-on experts (qwen2-moe style)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # token chunk size for dispatch (bounds the (E, C, d) gather buffer)
    dispatch_chunk: int = 4096


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent sub-config (mamba + xlstm families)."""

    kind: str = "mamba"            # "mamba" | "mlstm" | "slstm"
    d_state: int = 16              # mamba SSM state dim
    d_conv: int = 4                # mamba local conv width
    expand: int = 2                # mamba inner expansion
    chunk: int = 256               # chunkwise-parallel scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture, exactly as assigned from the public pool."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 -> full attention; >0 used for long_500k
    # norm / misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): per-layer block kinds, e.g. ("mamba",)*3+("attn",)+...
    block_pattern: Tuple[str, ...] = ()
    # layers at which MoE replaces the dense FFN ("every_2", "all", "none")
    moe_layer_rule: str = "all"
    # audio (whisper): encoder spec — decoder dims come from the main fields
    encoder_layers: int = 0
    encoder_frames: int = 0        # stub frontend: #frames of precomputed embeddings
    # vlm (paligemma): number of image patch embeddings prepended as prefix
    vision_patches: int = 0
    source: str = ""               # citation bracket from the assignment

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Sequence-mixer kind per layer."""
        if self.block_pattern:
            reps = -(-self.num_layers // len(self.block_pattern))
            return (self.block_pattern * reps)[: self.num_layers]
        if self.family == "ssm":
            assert self.ssm is not None
            if self.ssm.kind == "xlstm":
                # xLSTM paper interleaves sLSTM blocks sparsely among mLSTM blocks
                # (1:7 in the 350M configuration table).
                return tuple(
                    "slstm" if (i % 8 == 7) else "mlstm" for i in range(self.num_layers)
                )
            return (self.ssm.kind,) * self.num_layers
        return ("attn",) * self.num_layers

    def layer_has_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        if self.moe_layer_rule == "all":
            return True
        if self.moe_layer_rule == "every_2":
            return layer_idx % 2 == 1
        if self.moe_layer_rule == "dense_first":
            # kimi-k2 style: first layer dense, rest MoE
            return layer_idx >= 1
        return False

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.head_dim
        n_attn_per_layer = d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d
        ffn_dense = 3 * d * self.d_ff  # SwiGLU gate/up/down
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            if kind == "attn":
                total += n_attn_per_layer
            elif kind == "mamba":
                assert self.ssm is not None
                d_in = self.ssm.expand * d
                total += d * 2 * d_in + d_in * self.ssm.d_conv
                total += d_in * (self.ssm.d_state * 2 + 1) + d_in * d
            elif kind in ("mlstm", "slstm"):
                d_in = 2 * d
                total += d * d_in * 2 + 3 * d_in * hd + d_in * d  # rough proj count
            if self.layer_has_moe(i):
                m = self.moe
                total += (m.num_experts + m.num_shared_experts) * 3 * d * m.d_expert
                total += d * m.num_experts  # router
            elif self.d_ff > 0 and kind in ("attn", "mamba"):
                total += ffn_dense
            total += 2 * d  # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(self.layer_has_moe(i) for i in range(self.num_layers))
        inactive = n_moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * m.d_expert
        return full - inactive


@dataclass(frozen=True)
class InputShape:
    """An assigned (seq_len, global_batch, step-kind) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


@dataclass(frozen=True)
class SpeculationConfig:
    """Algorithm-1 speculation knobs (paper §3): stride schedule, prefetch,
    the speculation cache, and in-round verification dedup."""

    generation_stride: int = 4        # k: tokens generated per retrieval (Ram et al.)
    speculation_stride: int = 3       # s: spec steps per verification (fixed mode)
    use_os3: bool = False             # optimal speculation stride scheduler
    prefetch_top_k: int = 1           # 1 = top-1 cache update; 20/256 = prefetching
    # fleet-only: collapse byte-identical queries inside a round's merged
    # verification call before the collective — one KB row per unique query,
    # scattered back to slots. Output-invariant (retrieval is a pure function
    # of the query); FleetResult.merged_rows_saved counts the rows it saved.
    dedup_verification: bool = True
    os3_window: int = 5               # w for gamma estimation
    gamma_max: float = 0.6
    max_stride: int = 16
    cache_capacity: int = 4096


@dataclass(frozen=True)
class AsyncConfig:
    """Async (pipelined) verification knobs (paper §4, +A)."""

    async_verification: bool = False
    # adaptive overlap gate (single path's extra step AND the async fleet's
    # overlapped stride): only speculate under an in-flight verification when
    # the estimated verification latency exceeds ratio x a speculation step —
    # +A hurts cheap retrievers (ADR, paper Table 4), so 0 disables the gate
    # (always overlap) and a huge value disables the overlap itself.
    async_gate_ratio: float = 0.6
    # fleet-only: minimum overlapped sub-steps per round once the gate is
    # open, even past the verification window. The default 0 keeps the fleet
    # overlap strictly window-bounded (only sub-steps expected to hide under
    # b_est run, so an overlapped round can never cost more than a sync one
    # on the modeled timeline); tests raise it to force full-stride overlaps
    # deterministically on stacks whose retrieval is too cheap to hide work.
    async_min_overlap: int = 0


@dataclass(frozen=True)
class FaultConfig:
    """Fault tolerance on the fleet KB-call paths: retry with exponential
    backoff + a per-call deadline around the merged verification call
    (FleetServer._verify_merged) and the continuous seed / ride-along path.
    KB search is a pure function of the query (the invariant
    dedup_verification already rests on), so a retried call returns
    byte-identical rows — transient-fault recovery is output-preserving by
    construction (tests/test_faults.py)."""

    retry_max: int = 2                # retries after the first attempt
    retry_backoff_s: float = 0.0      # base backoff; retry i sleeps base*2^(i-1)
    # per-call deadline, 0 = none: a KB call that overruns it counts as timed
    # out, its rows are discarded, and the call is retried (determinism makes
    # the discard safe)
    retrieval_timeout_s: float = 0.0
    # a merged call that still fails after retries degrades the round to
    # speculation-only for its slots — affected requests are marked
    # status='degraded' and EXEMPT from byte-parity (the PR-7 exact-bit
    # pattern); False re-raises RetrievalFailed out of serve() instead
    degrade_on_failure: bool = True


@dataclass(frozen=True)
class QueueConfig:
    """Continuous-batching overload shedding: cap on ARRIVED requests allowed
    to wait for a slot (0 = unbounded; newest arrivals are turned away first,
    like a bounded admission queue), and a queueing-delay deadline past which
    a waiting request is retired with status='shed' rather than served long
    after its sender gave up (0 = none)."""

    max_queue_depth: int = 0
    queue_deadline_s: float = 0.0


# which nested sub-config each legacy flat knob lives in (the flat names are
# DEPRECATED aliases — see RaLMConfig)
_RALM_GROUPS = {
    "speculation": SpeculationConfig,
    "async_": AsyncConfig,
    "faults": FaultConfig,
    "queue": QueueConfig,
}
_RALM_GROUP_FIELDS = {
    g: tuple(f.name for f in dataclasses.fields(cls))
    for g, cls in _RALM_GROUPS.items()
}


@dataclass(frozen=True, init=False)
class RaLMConfig:
    """Serving-loop configuration for the paper's technique (§3–§4).

    Knobs are grouped into nested frozen sub-configs — ``speculation``
    (Algorithm-1 stride/prefetch/cache), ``async_`` (+A pipelining),
    ``faults`` (retry/deadline/degradation), ``queue`` (continuous-batching
    shedding) — plus the top-level generation and KNN-LM fields below.

    Back-compat: every sub-config field is also constructible and readable
    under its historical FLAT name (``RaLMConfig(speculation_stride=3)``,
    ``rcfg.async_gate_ratio``, ``dataclasses.replace(rcfg, use_os3=True)``)
    via ``__init__`` folding and read-only property aliases. The flat names
    are DEPRECATED: new code should pass/ read the nested groups
    (``rcfg.speculation.use_os3``)."""

    speculation: SpeculationConfig = SpeculationConfig()
    async_: AsyncConfig = AsyncConfig()
    faults: FaultConfig = FaultConfig()
    queue: QueueConfig = QueueConfig()
    # KNN-LM mode (§5.3)
    knnlm: bool = False
    knn_k: int = 8                    # neighbours interpolated
    knn_prefetch_next_n: int = 10     # spatial-locality cache update
    knn_lambda: float = 0.25          # interpolation weight
    # generation budget / shaping
    max_new_tokens: int = 128
    max_prompt_len: int = 512
    max_doc_len: int = 256

    def __init__(self, speculation: Optional[SpeculationConfig] = None,
                 async_: Optional[AsyncConfig] = None,
                 faults: Optional[FaultConfig] = None,
                 queue: Optional[QueueConfig] = None,
                 knnlm: bool = False, knn_k: int = 8,
                 knn_prefetch_next_n: int = 10, knn_lambda: float = 0.25,
                 max_new_tokens: int = 128, max_prompt_len: int = 512,
                 max_doc_len: int = 256, **flat):
        groups = {
            "speculation": speculation if speculation is not None
            else SpeculationConfig(),
            "async_": async_ if async_ is not None else AsyncConfig(),
            "faults": faults if faults is not None else FaultConfig(),
            "queue": queue if queue is not None else QueueConfig(),
        }
        # fold deprecated flat kwargs into their nested group
        for gname, fields in _RALM_GROUP_FIELDS.items():
            kw = {n: flat.pop(n) for n in fields if n in flat}
            if kw:
                groups[gname] = dataclasses.replace(groups[gname], **kw)
        if flat:
            raise TypeError(
                f"RaLMConfig got unexpected keyword argument(s): "
                f"{', '.join(sorted(flat))}")
        for gname, g in groups.items():
            object.__setattr__(self, gname, g)
        object.__setattr__(self, "knnlm", knnlm)
        object.__setattr__(self, "knn_k", knn_k)
        object.__setattr__(self, "knn_prefetch_next_n", knn_prefetch_next_n)
        object.__setattr__(self, "knn_lambda", knn_lambda)
        object.__setattr__(self, "max_new_tokens", max_new_tokens)
        object.__setattr__(self, "max_prompt_len", max_prompt_len)
        object.__setattr__(self, "max_doc_len", max_doc_len)


def _flat_alias(group: str, name: str) -> property:
    def get(self):
        return getattr(getattr(self, group), name)
    get.__doc__ = (f"DEPRECATED flat alias for ``{group}.{name}`` "
                   f"(kept for back-compat; prefer the nested field).")
    return property(get)


for _g, _names in _RALM_GROUP_FIELDS.items():
    for _n in _names:
        setattr(RaLMConfig, _n, _flat_alias(_g, _n))
del _g, _names, _n
