"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (kv=4) d_ff=0 (projections live inside the xLSTM blocks)
vocab=50304. mLSTM blocks carry a matrix memory per head (linear-attention-like,
chunkwise-parallel); sLSTM blocks are scalar-memory recurrences (lax.scan).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(kind="xlstm", chunk=256),
    source="arXiv:2405.04517",
)
