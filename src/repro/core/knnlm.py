"""KNN-LM serving (paper §5.3): retrieval for *every* generated token, next-token
distribution interpolated between the LM and a k-NN datastore.

RaLMSpec adaptations (paper §5.3):
  * cache-update rule: populate the cache with the next ``n`` datastore entries
    *after* each retrieved entry (spatial locality of consecutive training
    positions), instead of re-inserting the same entry;
  * relaxed verification: a speculative step is correct iff the *decoded token*
    matches the ground-truth decoded token (token-match equivalence) — matching all
    k=1024 neighbour sets exactly would be exponentially unlikely, matching the
    argmax of the interpolated distribution is both sufficient for output
    preservation and achievable.

Datastore scans delegate to the retrieval-backend layer: the retriever handed
in here is an :class:`~repro.retrieval.retrievers.ExactDenseRetriever` (or
IVF) over the KNN datastore, so the per-token scan executes on whichever
backend it was built with — flat numpy, the Pallas kernel with the datastore
resident on device, or the mesh-sharded collective
(``ExactDenseRetriever(ds, backend="sharded")``). Nothing in this module
special-cases the execution strategy; `benchmarks/bench_knnlm.py --backend`
sweeps it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import RaLMConfig
from repro.core.cache import DenseRetrievalCache
from repro.core.ralmspec import ServeResult
from repro.core.scheduler import OS3
from repro.retrieval.encoder import ContextEncoder


def knn_interpolate(lm_logits: np.ndarray, values: np.ndarray, scores: np.ndarray,
                    lam: float, beta: float = 8.0) -> int:
    """argmax of (1-lam)*softmax(lm) + lam*p_knn, p_knn = softmax(beta*scores) mass
    scattered onto each neighbour's target token. Deterministic given inputs."""
    V = lm_logits.shape[-1]
    x = lm_logits.astype(np.float64)
    x = x - x.max()
    p_lm = np.exp(x)
    p_lm /= p_lm.sum()
    valid = values >= 0
    p_knn = np.zeros(V, np.float64)
    if valid.any():
        s = scores[valid].astype(np.float64) * beta
        s = np.exp(s - s.max())
        s /= s.sum()
        np.add.at(p_knn, values[valid], s)
    p = (1.0 - lam) * p_lm + lam * p_knn
    return int(np.argmax(p))


class KNNLMBase:
    def __init__(self, engine, retriever, rcfg: RaLMConfig, encoder: ContextEncoder):
        self.engine = engine
        self.retriever = retriever
        self.rcfg = rcfg
        self.encoder = encoder
        self.kb = retriever.kb
        if getattr(self.kb, "values", None) is None:
            raise ValueError(
                "KNN-LM serving needs a value-carrying datastore "
                "(DenseKB from build_knn_datastore); got a KB without "
                "per-entry values")

    def _query(self) -> np.ndarray:
        return self.encoder.encode(self.engine.tokens)

    def _done(self) -> bool:
        return (self.engine.finished
                or len(self.engine.generated) >= self.rcfg.max_new_tokens)


class KNNLMSeq(KNNLMBase):
    """Baseline: one KB retrieval per generated token (Khandelwal et al. 2019)."""

    def serve(self, prompt: Sequence[int]) -> ServeResult:
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        eng.stats.reset()
        r0t, r0c, r0q = r.stats.time, r.stats.calls, r.stats.queries
        r0m = r.stats.modeled_time
        t0 = time.perf_counter()
        eng.start(list(prompt)[-rcfg.max_prompt_len:])
        while not self._done():
            q = self._query()
            ids, sc = r.retrieve(q[None], rcfg.knn_k)
            vals = self.kb.values[ids[0]]
            tok = knn_interpolate(eng.peek_logits(), vals, sc[0], rcfg.knn_lambda)
            eng.advance(tok)
        wall = time.perf_counter() - t0
        measured_r = r.stats.time - r0t
        return ServeResult(tokens=list(eng.generated), wall_time=wall,
                           analytic_time=wall - measured_r
                           + (r.stats.modeled_time - r0m),
                           gen_time=eng.stats.gen_time,
                           retrieval_time=measured_r,
                           kb_calls=r.stats.calls - r0c,
                           kb_queries=r.stats.queries - r0q)


class KNNLMSpec(KNNLMBase):
    """Speculative KNN-LM serving with the modified cache-update + verification."""

    def serve(self, prompt: Sequence[int]) -> ServeResult:
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        eng.stats.reset()
        r0t, r0c, r0q = r.stats.time, r.stats.calls, r.stats.queries
        os3 = OS3(window=rcfg.os3_window, gamma_max=rcfg.gamma_max,
                  max_stride=rcfg.max_stride) if rcfg.use_os3 else None
        res = ServeResult(tokens=[], wall_time=0, analytic_time=0, gen_time=0,
                          retrieval_time=0, kb_calls=0, kb_queries=0)
        t0 = time.perf_counter()
        analytic = 0.0

        eng.start(list(prompt)[-rcfg.max_prompt_len:])
        cache = DenseRetrievalCache(self.kb.embeddings.shape[1],
                                    rcfg.cache_capacity)
        q0 = self._query()
        ids0, _ = r.retrieve(q0[None], rcfg.knn_k)
        analytic += r.stats.model_latency(1)
        self._spatial_insert(cache, ids0[0])

        while not self._done():
            stride = os3.stride if os3 else rcfg.speculation_stride
            snaps, queries, lm_logits, spec_toks, a_times = [], [], [], [], []
            while len(spec_toks) < max(stride, 1) and not self._done():
                ta = time.perf_counter()
                snaps.append(eng.snapshot())
                q = self._query()
                ids, sc = cache.retrieve(q, rcfg.knn_k)
                vals = np.where(ids >= 0, self.kb.values[np.maximum(ids, 0)], -1)
                logits = eng.peek_logits()
                tok = knn_interpolate(logits, vals, sc, rcfg.knn_lambda)
                eng.advance(tok)
                a = time.perf_counter() - ta
                queries.append(q)
                lm_logits.append(logits)
                spec_toks.append(tok)
                a_times.append(a)
                analytic += a
                if os3:
                    os3.record_speculation(a)
            if not spec_toks:
                break
            res.spec_steps += len(spec_toks)
            res.strides.append(len(spec_toks))

            tb = time.perf_counter()
            gt_ids, gt_sc = r.retrieve(np.stack(queries), rcfg.knn_k)
            b_lat = time.perf_counter() - tb
            b_model = r.stats.model_latency(len(queries))
            analytic += b_model

            m = len(spec_toks)
            for i in range(len(spec_toks)):
                gt_vals = self.kb.values[gt_ids[i]]
                gt_tok = knn_interpolate(lm_logits[i], gt_vals, gt_sc[i],
                                         rcfg.knn_lambda)
                if gt_tok != spec_toks[i]:
                    m = i
                    gt_correct = gt_tok
                    break
            for i in range(len(spec_toks)):
                self._spatial_insert(cache, gt_ids[i])
            if os3:
                os3.record_verification(b_model, len(spec_toks), m)
            res.rounds += 1

            if m < len(spec_toks):
                res.mismatches += 1
                eng.restore(snaps[m])
                tc = time.perf_counter()
                eng.advance(gt_correct)
                analytic += time.perf_counter() - tc

        res.tokens = list(eng.generated)
        res.wall_time = time.perf_counter() - t0
        res.analytic_time = analytic
        res.gen_time = eng.stats.gen_time
        res.retrieval_time = r.stats.time - r0t
        res.kb_calls = r.stats.calls - r0c
        res.kb_queries = r.stats.queries - r0q
        return res

    def _spatial_insert(self, cache: DenseRetrievalCache, ids_row) -> None:
        """Paper §5.3 cache rule: insert the next-n entries *after* each retrieved
        datastore position (consecutive positions = spatial locality)."""
        N = self.kb.size
        want = []
        for did in ids_row:
            did = int(did)
            if did < 0:
                continue
            want.extend(range(did, min(did + self.rcfg.knn_prefetch_next_n + 1, N)))
        want = [w for w in dict.fromkeys(want) if w not in cache]
        if want:
            cache.insert(want, self.kb.embeddings[want], self.kb.values[want])
