"""RaLMSpec: speculative retrieval + batched verification for iterative RaLM serving
(paper Algorithm 1), plus the RaLMSeq baseline (Ram et al. 2023 style: retrieve every
k generated tokens, prepend-replace the latest chunk).

Output preservation: RaLMSpec.serve() produces *exactly* the token sequence of
RaLMSeq.serve() for the same request (greedy decoding + rank-preserving cache +
rollback-on-mismatch), and the multi-request fleet paths preserve it per slot:
repro.serving.fleet.FleetServer at any fixed concurrency, and
repro.serving.continuous.ContinuousFleetServer under continuous batching — no
matter when a request is admitted, which slot it lands in, or what rollbacks its
slot neighbors take. tests/test_system.py asserts the single-request claim;
tests/test_output_preservation.py the batched-engine and fixed-fleet claims;
tests/test_continuous.py the continuous-batching claim, each for every retriever
type. Together they guard the paper's central claim.

Per-request Algorithm-1 state (the speculation cache, the async carry, the OS^3
scheduler instance, and the latency ledger) lives in :class:`RequestState` so the
single-request server here and BOTH fleet servers drive the *same* state machine.
The carry is a per-request list of speculative steps taken while a verification
call was in flight: the single-request path carries at most one extra step
(paper Figure 3), while the async fleet path
(:class:`repro.serving.fleet.FleetServer` with ``async_rounds``) overlaps the
merged verification call with the whole next lockstep stride and carries every
overlapped step of each fully-verified slot:

  * ``repro.serving.fleet.FleetServer`` runs N of them in lockstep over a fixed
    request group,
  * ``repro.serving.continuous.ContinuousFleetServer`` runs them over a slot
    pool with continuous batching — requests are admitted into slots the moment
    they free up mid-flight and retired as they finish, so ``RequestState`` also
    carries request identity (``rid``), a per-request token budget (``max_new``),
    and the modeled arrival/admission/finish clock.

Each round, every live slot's verification queries merge into one batched KB call
(cross-request batched verification; §A.1 shows batched retrieval is
near-constant-cost for EDR/SR, so the merged call amortizes).

Latency ledger: wall-clock segments are recorded per component (G = prefill+decode,
R = retrieval) exactly like the paper's Figure 4 decomposition. Async verification
additionally maintains the paper's *analytic* ideal-overlap timeline (their §5.1
simulated latency) next to the real threaded overlap.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import RaLMConfig
from repro.core.cache import (DenseRetrievalCache, SharedCacheView,
                              SharedRetrievalCache, SparseRetrievalCache,
                              query_key)
from repro.core.scheduler import OS3
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.faults import RetrievalFailed, RetrievalTimeout
from repro.retrieval.retrievers import BM25Retriever


@dataclass
class ServeResult:
    tokens: List[int]
    wall_time: float
    analytic_time: float
    gen_time: float
    retrieval_time: float
    kb_calls: int
    kb_queries: int
    rounds: int = 0
    mismatches: int = 0
    spec_steps: int = 0
    strides: List[int] = field(default_factory=list)
    # async overlap accounting: speculative steps taken while a verification
    # call was in flight and kept (carry_steps) vs thrown away because the
    # round they overlapped mis-speculated (carry_invalidations)
    carry_steps: int = 0
    carry_invalidations: int = 0
    # fault-tolerance status: 'ok' | 'degraded' (a merged verification call
    # failed after retries while this request was live — some of its rounds
    # served speculation-only, so it is EXEMPT from the byte-parity claim,
    # mirroring the quantized backends' exact-bit pattern) | 'shed' (retired
    # by continuous-batching load shedding before serving a single token)
    status: str = "ok"

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def speedup_denominator(self) -> float:
        return self.wall_time


def _chunk(doc: Sequence[int], chunk_len: int) -> tuple:
    """Fixed-length doc chunk (paper: max retrieved chunk length, padded for jit
    shape reuse; pad token 1 is reserved)."""
    d = list(doc)[:chunk_len]
    return tuple(d + [1] * (chunk_len - len(d)))


def first_mismatch(specs: Sequence[int], gt_ids) -> int:
    """Index of the first speculated doc id that disagrees with the verified top-1
    (Algorithm 1 line 9); == len(specs) when the whole stride verified."""
    for i in range(len(specs)):
        if int(specs[i]) != int(gt_ids[i][0]):
            return i
    return len(specs)


def dedup_queries(queries):
    """Collapse duplicate queries ahead of a merged verification call.

    -> (unique_queries, inverse) with ``queries[i] == unique_queries[inverse[i]]``
    (byte-equality via :func:`query_key`). The KB retrieves one row per UNIQUE
    query and the caller scatters rows back to slots with ``rows[inverse]`` —
    output-invariant because retrieval is a pure function of the query, so
    identical queries get identical rows either way.
    """
    uniq, inverse, index = [], [], {}
    for q in queries:
        key = query_key(q)
        pos = index.get(key)
        if pos is None:
            pos = index[key] = len(uniq)
            uniq.append(q)
        inverse.append(pos)
    return uniq, np.asarray(inverse, np.int64)


@dataclass
class RequestState:
    """Per-request Algorithm-1 state, shared by the single-request server and the
    fleet path: the speculation cache, the OS^3 scheduler instance, the async
    carry, the analytic timeline, the result ledger, and the current round's
    scratch (snapshots / queries / speculated ids / per-step latencies)."""

    cache: object
    os3: Optional[OS3]
    res: ServeResult
    analytic: float = 0.0
    # multi-step async carry: [(snap, query, spec_id, a_latency[, aux]), ...]
    # of UNVERIFIED speculative steps taken while the previous round's
    # verification call was in flight. The single-request path carries at most
    # one step; the async fleet carries up to a whole overlapped stride. The
    # optional 5th element is the workload's per-step auxiliary record (the
    # iterative-RaLM workload has none; KNN-LM carries the LM logits its
    # token-match verification recomputes against).
    carry: List[tuple] = field(default_factory=list)
    snaps: List = field(default_factory=list)
    queries: List = field(default_factory=list)
    specs: List[int] = field(default_factory=list)
    a_times: List[float] = field(default_factory=list)
    aux: List = field(default_factory=list)
    # continuous-batching identity + timing (repro.serving.continuous): which
    # request this state belongs to, its own token budget, and where it sits on
    # the modeled clock. The lockstep paths leave these at their defaults.
    rid: int = -1                      # request id (stable across slot reuse)
    max_new: Optional[int] = None      # per-request budget; None -> rcfg's
    arrival: float = 0.0               # modeled time the request arrived
    admitted: float = 0.0              # modeled time it won a slot
    finished: float = 0.0              # modeled time it was retired

    def stride(self, rcfg: RaLMConfig) -> int:
        return self.os3.stride if self.os3 else rcfg.speculation_stride

    def budget_limit(self, rcfg: RaLMConfig) -> int:
        """Token budget for THIS request (per-request under continuous batching)."""
        return self.max_new if self.max_new is not None else rcfg.max_new_tokens

    def begin_round(self) -> None:
        """Reset the round scratch, pre-loading any carried (already executed,
        not yet verified) overlap steps — their latencies ride along in
        ``a_times`` but are NOT re-charged to the analytic timeline (they were
        paid under the previous round's ``max(a_overlap, b)``)."""
        self.snaps, self.queries, self.specs = [], [], []
        self.a_times, self.aux = [], []
        for step in self.carry:
            self.record_step(*step)
        self.carry = []

    def record_step(self, snap, query, spec_id: int, a_latency: float,
                    aux=None) -> None:
        self.snaps.append(snap)
        self.queries.append(query)
        self.specs.append(spec_id)
        self.a_times.append(a_latency)
        self.aux.append(aux)


class _ServerBase:
    def __init__(self, engine, retriever, rcfg: RaLMConfig,
                 encoder: Optional[ContextEncoder] = None, chunk_len: int = 64,
                 shared_cache: Optional[SharedRetrievalCache] = None):
        self.engine = engine
        self.retriever = retriever
        self.rcfg = rcfg
        self.encoder = encoder
        self.chunk_len = chunk_len
        self.sparse = isinstance(retriever, BM25Retriever)
        # fleet-scale shared speculation tier (None = per-request caches only).
        # Strictly a speculation source: verification still confirms every doc.
        self.shared_cache = shared_cache
        # whether per-request OS^3 instances optimize the async objective;
        # FleetServer overrides this when pipelined (async) rounds are on
        self._os3_async = rcfg.async_verification
        # modeled cost of failed KB-call attempts (retries, backoff): the
        # guarded call accumulates it here — possibly from the verification
        # worker thread — and the round loop drains it into the analytic
        # timeline after the join
        self._ft_lock = threading.Lock()
        self._ft_overhead = 0.0

    def _query_tokens(self, toks):
        """Context-dependent query summarizing an explicit context (paper §1) —
        the fleet path passes per-slot token lists through here."""
        if self.sparse:
            return list(toks[-32:])
        return self.encoder.encode(toks)

    def _query(self):
        return self._query_tokens(self.engine.tokens)

    def _retrieve_batch(self, queries, k: int):
        if self.sparse:
            return self.retriever.retrieve(queries, k)
        return self.retriever.retrieve(np.stack(queries), k)

    def _retrieve_guarded(self, queries, k: int):
        """The fault-tolerance shell around a KB call: per-call deadline +
        exponential-backoff retry (``rcfg.retry_max`` / ``retry_backoff_s`` /
        ``retrieval_timeout_s``). KB search is a pure function of the query,
        so a retried call returns byte-identical rows and recovery from any
        transient fault schedule is output-preserving by construction
        (tests/test_faults.py). The deadline is enforced post hoc — a call
        that overruns it completes, but its rows are discarded and the call
        retried, which the same determinism makes safe.

        Raises :class:`~repro.retrieval.faults.RetrievalFailed` once the
        budget is exhausted; the fleet round loop degrades gracefully.
        Failed attempts are charged to the analytic timeline at the modeled
        batched-call cost (plus any real backoff sleeps) via the
        ``_ft_overhead`` accumulator, and counted on ``RetrieverStats``."""
        rcfg, stats = self.rcfg, self.retriever.stats
        last = None
        for attempt in range(rcfg.retry_max + 1):
            final = attempt == rcfg.retry_max
            if attempt:
                backoff = rcfg.retry_backoff_s * (2 ** (attempt - 1))
                if backoff:
                    time.sleep(backoff)
                with self._ft_lock:
                    self._ft_overhead += backoff
            t0 = time.perf_counter()
            try:
                ids, scores = self._retrieve_batch(queries, k)
            except Exception as e:     # any backend fault is assumed transient
                last = e
                stats.record_failure("error", final=final)
                with self._ft_lock:
                    self._ft_overhead += stats.model_latency(len(queries))
                continue
            dt = time.perf_counter() - t0
            if rcfg.retrieval_timeout_s and dt > rcfg.retrieval_timeout_s:
                last = RetrievalTimeout(
                    f"KB call took {dt:.3f}s > "
                    f"{rcfg.retrieval_timeout_s:.3f}s deadline")
                stats.record_failure("timeout", final=final)
                with self._ft_lock:
                    self._ft_overhead += stats.model_latency(len(queries))
                continue
            return ids, scores
        raise RetrievalFailed(
            f"KB call failed after {rcfg.retry_max + 1} attempts") from last

    def _take_ft_overhead(self) -> float:
        """Drain the modeled cost of failed attempts accumulated since the
        last drain (thread-safe: the guarded call may run on the worker)."""
        with self._ft_lock:
            o, self._ft_overhead = self._ft_overhead, 0.0
            return o

    def _doc(self, doc_id: int) -> tuple:
        return _chunk(self.retriever.kb.docs[int(doc_id)], self.chunk_len)

    def _done(self) -> bool:
        return (self.engine.finished
                or len(self.engine.generated) >= self.rcfg.max_new_tokens)

    def _budget(self) -> int:
        return self.rcfg.max_new_tokens - len(self.engine.generated)

    # ---- per-request state (shared with the fleet path) ----------------------------
    def _new_cache(self):
        if self.sparse:
            local = SparseRetrievalCache(self.retriever.kb,
                                         self.rcfg.cache_capacity)
        else:
            local = DenseRetrievalCache(self.retriever.kb.embeddings.shape[1],
                                        self.rcfg.cache_capacity)
        if self.shared_cache is not None:
            return SharedCacheView(local, self.shared_cache)
        return local

    def _shared_put(self, queries, ids, scores) -> None:
        """Publish verified KB rows to the shared tier (no-op when disabled).
        Called from whichever thread ran the verification call — the tier is
        lock-guarded, so the async worker may publish while the main thread's
        overlapped speculation stride is reading."""
        if self.shared_cache is None:
            return
        for q, row_i, row_s in zip(queries, ids, scores):
            self.shared_cache.put(q, row_i, row_s)

    def _cache_insert(self, cache, ids_row):
        ids_row = [int(i) for i in ids_row if int(i) >= 0]
        if not ids_row:
            return
        if self.sparse:
            cache.insert(ids_row)
        else:
            cache.insert(ids_row, self.retriever.keys_of(ids_row))

    def _new_request_state(self, cache=None, rid: int = -1,
                           max_new: Optional[int] = None) -> RequestState:
        rcfg = self.rcfg
        os3 = OS3(window=rcfg.os3_window, gamma_max=rcfg.gamma_max,
                  max_stride=rcfg.max_stride,
                  async_mode=self._os3_async) if rcfg.use_os3 else None
        return RequestState(
            cache=cache if cache is not None else self._new_cache(), os3=os3,
            rid=rid, max_new=max_new,
            res=ServeResult(tokens=[], wall_time=0, analytic_time=0, gen_time=0,
                            retrieval_time=0, kb_calls=0, kb_queries=0))


class RaLMSeq(_ServerBase):
    """The paper's baseline: one KB retrieval every generation stride."""

    def serve(self, prompt: Sequence[int]) -> ServeResult:
        eng, r = self.engine, self.retriever
        eng.stats.reset()
        r0c, r0q, r0t = r.stats.calls, r.stats.queries, r.stats.time
        r0m = r.stats.modeled_time
        t0 = time.perf_counter()
        eng.start(list(prompt)[-self.rcfg.max_prompt_len:])
        while not self._done():
            q = self._query()
            ids, _ = self._retrieve_batch([q], 1)
            eng.set_doc(self._doc(ids[0, 0]))
            eng.gen(min(self.rcfg.generation_stride, self._budget()))
        wall = time.perf_counter() - t0
        measured_r = r.stats.time - r0t
        modeled_r = r.stats.modeled_time - r0m
        return ServeResult(
            tokens=list(eng.generated), wall_time=wall,
            analytic_time=wall - measured_r + modeled_r,
            gen_time=eng.stats.gen_time, retrieval_time=measured_r,
            kb_calls=r.stats.calls - r0c, kb_queries=r.stats.queries - r0q)


class RaLMSpec(_ServerBase):
    """Algorithm 1 with optional Prefetching (P), OS^3 (S), Async verification (A).

    ``persistent_cache=True`` (beyond-paper) keeps retrieval results across
    requests instead of the paper's per-request cache: topically-related requests
    warm each other's speculation. It is implemented as a private
    :class:`SharedRetrievalCache` (the same lock-guarded tier the fleet servers
    share), so it is safe even when the async verification worker publishes
    results while the main thread speculates. Output preservation is unaffected —
    cache contents only steer *speculation*; verification still compares against
    the KB.
    """

    def __init__(self, engine, retriever, rcfg: RaLMConfig,
                 encoder: Optional[ContextEncoder] = None, chunk_len: int = 64,
                 persistent_cache: bool = False,
                 shared_cache: Optional[SharedRetrievalCache] = None):
        if persistent_cache and shared_cache is None:
            shared_cache = SharedRetrievalCache(capacity=rcfg.cache_capacity)
        super().__init__(engine, retriever, rcfg, encoder, chunk_len,
                         shared_cache=shared_cache)
        self._pool = ThreadPoolExecutor(max_workers=1) \
            if rcfg.async_verification else None

    def serve(self, prompt: Sequence[int]) -> ServeResult:
        eng, r, rcfg = self.engine, self.retriever, self.rcfg
        eng.stats.reset()
        r0c, r0q, r0t = r.stats.calls, r.stats.queries, r.stats.time
        rs = self._new_request_state()
        res = rs.res
        t0 = time.perf_counter()

        eng.start(list(prompt)[-rcfg.max_prompt_len:])
        # Algorithm 1 line 4: initial retrieval populates the cache (prefetched)
        q0 = self._query()
        ids0, s0 = self._retrieve_batch([q0], max(rcfg.prefetch_top_k, 1))
        rs.analytic += r.stats.model_latency(1)
        self._cache_insert(rs.cache, ids0[0])
        self._shared_put([q0], ids0, s0)

        # NB: a pending carry (async overlap's extra speculative step) is an
        # UNVERIFIED speculative stride — the loop must not exit on budget/EOS
        # until it has been verified (and corrected if wrong), or output
        # preservation breaks on the final stride.
        while not self._done() or rs.carry:
            stride = rs.stride(rcfg)
            rs.begin_round()
            while len(rs.specs) < max(stride, 1) and not self._done():
                snap, q, did, a = self._spec_step(rs.cache)
                rs.record_step(snap, q, did, a)
                rs.analytic += a
                if rs.os3:
                    rs.os3.record_speculation(a)
            if not rs.specs:
                break
            res.spec_steps += len(rs.specs)
            res.strides.append(len(rs.specs))

            if self._pool is not None:
                fut = self._pool.submit(self._verify, rs.queries)
                # asynchronous extra speculation step (paper Figure 3) — adaptive:
                # only speculate while verification is actually pending. When the
                # retriever is cheaper than one speculation step (ADR), the extra
                # step is pure downside (paper Table 4 observes exactly this: +A
                # *hurts* ADR); waiting out the short verification costs less.
                extra = None
                b_est = self.retriever.stats.model_latency(len(rs.queries))
                a_est = sum(rs.a_times) / max(len(rs.a_times), 1)
                if (not fut.done() and b_est > rcfg.async_gate_ratio * a_est
                        and not self._done()):
                    extra = self._spec_step(rs.cache)
                gt_ids, b_lat, b_model = fut.result()
                # analytic ideal (paper §4): the verification latency hides behind
                # the extra speculation step — the round pays max(a_extra, b), and the
                # extra step's own a is *not* double-counted when carried over.
                rs.analytic += max(extra[3], b_model) if extra is not None else b_model
            else:
                gt_ids, b_lat, b_model = self._verify(rs.queries)
                rs.analytic += b_model
                extra = None

            # cache update: top-1 or top-k (prefetch) per verified query
            for row in gt_ids:
                self._cache_insert(rs.cache, row[:max(rcfg.prefetch_top_k, 1)])

            m = first_mismatch(rs.specs, gt_ids)
            if rs.os3:
                rs.os3.record_verification(b_model, len(rs.specs), m)
            res.rounds += 1

            if m < len(rs.specs):                   # mis-speculation: rollback
                res.mismatches += 1
                if extra is not None:               # extra step is invalid too
                    res.carry_invalidations += 1
                    extra = None
                self.engine.restore(rs.snaps[m])
                tc = time.perf_counter()
                self.engine.set_doc(self._doc(gt_ids[m, 0]))
                self.engine.gen(min(self.rcfg.generation_stride, self._budget()))
                rs.analytic += time.perf_counter() - tc
            if extra is not None:
                rs.carry = [extra]
                res.carry_steps += 1
                if rs.os3:
                    rs.os3.record_speculation(extra[3])

        res.tokens = list(eng.generated)
        res.wall_time = time.perf_counter() - t0
        res.analytic_time = rs.analytic
        res.gen_time = eng.stats.gen_time
        res.retrieval_time = r.stats.time - r0t
        res.kb_calls = r.stats.calls - r0c
        res.kb_queries = r.stats.queries - r0q
        return res

    # ---- helpers ----------------------------------------------------------------------
    def _spec_step(self, cache):
        """One speculative retrieval + generation stride. Returns
        (snapshot, query, speculated_doc_id, latency)."""
        t0 = time.perf_counter()
        snap = self.engine.snapshot()
        q = self._query()
        ids, _ = cache.retrieve(q, 1)
        did = int(ids[0])
        if did >= 0:
            self.engine.set_doc(self._doc(did))
        # did < 0 (cold cache) keeps the previous doc; verification will correct.
        self.engine.gen(min(self.rcfg.generation_stride, self._budget()))
        return snap, q, did, time.perf_counter() - t0

    def _verify(self, queries):
        """Batched KB retrieval (the verification step).

        Returns (ids, wall_latency, modeled_latency) — the modeled value follows the
        paper's §A.1 batched-latency shape (see RetrieverStats) and feeds the
        analytic timeline + OS^3; wall-clock always reported alongside.

        Runs on the async worker thread when async verification is on, so the
        shared-tier publish below relies on SharedRetrievalCache's lock."""
        t0 = time.perf_counter()
        k = max(self.rcfg.prefetch_top_k, 1)
        ids, scores = self._retrieve_batch(queries, k)
        self._shared_put(queries, ids, scores)
        return ids, time.perf_counter() - t0, \
            self.retriever.stats.model_latency(len(queries))
