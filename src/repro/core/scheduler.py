"""OS^3 — the Optimal Speculation Stride Scheduler (paper §4, appendix A.2).

Maximizes expected verified-documents-per-second:

  sync:   E(s) = (1 - g^s) / ((1 - g) * (s*a + b))
  async:  E(s) = (1 - g^s) / ((1 - g) * [g^s*((s-1)a + max(a,b)) + (1-g^s)*(s*a + b)])

with a = speculation-step latency (cache retrieval + LM decode stride), b =
verification latency (batched KB retrieval), g = speculation accuracy.

g is estimated by the paper's windowed MLE over the last w verification outcomes:
  g_hat = sum(M) / (sum(M) + sum(1[M < s]))           (A.2)
capped at gamma_max to avoid division blow-up as g_hat -> 1.
a, b are estimated from recent profiling (EMA over the same window).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional


def expected_verified(gamma: float, s: int) -> float:
    """(1 - g^s) / (1 - g), continuous-safe at g == 1."""
    if abs(1.0 - gamma) < 1e-9:
        return float(s)
    return (1.0 - gamma ** s) / (1.0 - gamma)


def objective(gamma: float, s: int, a: float, b: float, async_mode: bool) -> float:
    n = expected_verified(gamma, s)
    if async_mode:
        hit = gamma ** s
        lat = hit * ((s - 1) * a + max(a, b)) + (1.0 - hit) * (s * a + b)
    else:
        lat = s * a + b
    return n / max(lat, 1e-12)


@dataclass
class OS3:
    window: int = 5
    gamma_max: float = 0.6
    max_stride: int = 16
    async_mode: bool = False
    init_stride: int = 1
    a_init: float = 1e-3
    b_init: float = 1e-3

    def __post_init__(self):
        self._matches = deque(maxlen=self.window)    # M(s(t), X)
        self._strides = deque(maxlen=self.window)    # s(t)
        self._a = deque(maxlen=self.window)
        self._b = deque(maxlen=self.window)
        self.stride = self.init_stride

    # ---- profiling ------------------------------------------------------------------
    def record_speculation(self, latency: float) -> None:
        self._a.append(latency)

    def record_verification(self, latency: float, stride: int, matched: int,
                            n_participants: int = 1) -> None:
        """Record one verification outcome. ``n_participants`` amortizes a
        fleet round's shared batched KB call across the slots it served: each
        slot's effective b observation is ``latency / n_participants`` (the
        §A.1 cross-request amortization), which is the b the async objective
        must weigh against a when the fleet pipelines rounds."""
        self._b.append(latency / max(n_participants, 1))
        self._strides.append(stride)
        self._matches.append(matched)
        self.stride = self.optimal_stride()

    # ---- estimators -----------------------------------------------------------------
    @property
    def a(self) -> float:
        return sum(self._a) / len(self._a) if self._a else self.a_init

    @property
    def b(self) -> float:
        return sum(self._b) / len(self._b) if self._b else self.b_init

    @property
    def gamma(self) -> float:
        """Windowed MLE (paper A.2): matches are Bernoulli successes; a verification
        round with M < s contributes one observed failure."""
        if not self._matches:
            return 0.5
        num = sum(self._matches)
        fails = sum(1 for m, s in zip(self._matches, self._strides) if m < s)
        g = num / max(num + fails, 1)
        return min(g, self.gamma_max)

    # ---- solver ---------------------------------------------------------------------
    def optimal_stride(self, gamma: Optional[float] = None, a: Optional[float] = None,
                       b: Optional[float] = None) -> int:
        g = self.gamma if gamma is None else gamma
        a = self.a if a is None else a
        b = self.b if b is None else b
        best_s, best_v = 1, -1.0
        for s in range(1, self.max_stride + 1):
            v = objective(g, s, a, b, self.async_mode)
            if v > best_v:
                best_s, best_v = s, v
        return best_s
