"""The per-request local retrieval cache (paper §3, Figure 2).

Not an exact-match cache: retrieval from the cache uses the *same scoring metric* as
the knowledge-base retriever, over the (much smaller) set of cached entries. This
gives the paper's rank-preservation property: if the KB top-1 document for a query is
present in the cache, cache retrieval returns exactly that document
(proved as a hypothesis property test in tests/test_cache_properties.py).

DenseRetrievalCache  — keys are embeddings, score = inner product (EDR/ADR/KNN-LM).
SparseRetrievalCache — keys are per-doc term arrays; score = BM25 with the *global*
                       corpus statistics (idf, avgdl) captured at construction, so the
                       cache score of a doc equals its KB score exactly.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.retrieval.kb import SparseKB


class DenseRetrievalCache:
    def __init__(self, d: int, capacity: int = 4096):
        self.capacity = capacity
        self.d = d
        self._keys = np.zeros((capacity, d), np.float32)
        self._ids = np.full((capacity,), -1, np.int64)
        self._values = np.full((capacity,), -1, np.int64)   # optional payload
        self._order: OrderedDict = OrderedDict()            # id -> slot (LRU)
        self._free = list(range(capacity - 1, -1, -1))
        self.size = 0

    def __contains__(self, doc_id) -> bool:
        return int(doc_id) in self._order

    def insert(self, ids, keys, values=None) -> None:
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        keys = np.atleast_2d(np.asarray(keys, np.float32))
        vals = (np.atleast_1d(np.asarray(values, np.int64))
                if values is not None else np.full(len(ids), -1, np.int64))
        for i, did in enumerate(ids):
            did = int(did)
            if did in self._order:                          # refresh LRU
                self._order.move_to_end(did)
                continue
            if not self._free:                              # evict LRU
                old, slot = self._order.popitem(last=False)
                self._free.append(slot)
                self.size -= 1
            slot = self._free.pop()
            self._keys[slot] = keys[i]
            self._ids[slot] = did
            self._values[slot] = vals[i]
            self._order[did] = slot
            self.size += 1

    def retrieve(self, query: np.ndarray, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """-> (ids (k,), scores (k,)); ids are -1 if the cache holds < k entries."""
        if self.size == 0:
            return np.full((k,), -1, np.int64), np.full((k,), -np.inf, np.float32)
        slots = np.fromiter(self._order.values(), np.int64, len(self._order))
        s = self._keys[slots] @ np.asarray(query, np.float32)
        kk = min(k, len(slots))
        top = np.argpartition(-s, kth=kk - 1)[:kk] if kk < len(slots) else np.argsort(-s)[:kk]
        top = top[np.argsort(-s[top], kind="stable")]
        ids = self._ids[slots[top]]
        sc = s[top]
        for did in ids:                                     # LRU touch
            self._order.move_to_end(int(did))
        if kk < k:
            ids = np.pad(ids, (0, k - kk), constant_values=-1)
            sc = np.pad(sc, (0, k - kk), constant_values=-np.inf)
        return ids, sc

    def values_of(self, ids) -> np.ndarray:
        out = []
        for did in np.atleast_1d(ids):
            slot = self._order.get(int(did), None)
            out.append(self._values[slot] if slot is not None else -1)
        return np.asarray(out, np.int64)


class SparseRetrievalCache:
    """BM25-scored cache. Stores per-doc term arrays; corpus stats come from the KB
    (global, fixed) so local scores == KB scores for any cached doc."""

    def __init__(self, kb: SparseKB, capacity: int = 4096):
        self.kb = kb
        self.capacity = capacity
        L = kb.terms.shape[1]
        self._terms = np.full((capacity, L), -1, np.int32)
        self._dl = np.zeros((capacity,), np.float32)
        self._ids = np.full((capacity,), -1, np.int64)
        self._order: OrderedDict = OrderedDict()
        self._free = list(range(capacity - 1, -1, -1))
        self.size = 0

    def __contains__(self, doc_id) -> bool:
        return int(doc_id) in self._order

    def insert(self, ids, keys=None, values=None) -> None:
        for did in np.atleast_1d(np.asarray(ids, np.int64)):
            did = int(did)
            if did in self._order:
                self._order.move_to_end(did)
                continue
            if not self._free:
                _, slot = self._order.popitem(last=False)
                self._free.append(slot)
                self.size -= 1
            slot = self._free.pop()
            self._terms[slot] = self.kb.terms[did]
            self._dl[slot] = self.kb.doc_len[did]
            self._ids[slot] = did
            self._order[did] = slot
            self.size += 1

    def retrieve(self, query_terms, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        if self.size == 0:
            return np.full((k,), -1, np.int64), np.full((k,), -np.inf, np.float32)
        slots = np.fromiter(self._order.values(), np.int64, len(self._order))
        T = self._terms[slots]
        dl = self._dl[slots]
        norm = self.kb.k1 * (1 - self.kb.b + self.kb.b * dl / self.kb.avgdl)
        s = np.zeros(len(slots), np.float32)
        for t in query_terms:
            idf = self.kb.idf.get(int(t))
            if idf is None:
                continue
            tf = (T == int(t)).sum(1).astype(np.float32)
            s += idf * tf * (self.kb.k1 + 1) / (tf + norm)
        kk = min(k, len(slots))
        top = np.argsort(-s, kind="stable")[:kk]
        ids = self._ids[slots[top]]
        sc = s[top]
        for did in ids:
            self._order.move_to_end(int(did))
        if kk < k:
            ids = np.pad(ids, (0, k - kk), constant_values=-1)
            sc = np.pad(sc, (0, k - kk), constant_values=-np.inf)
        return ids, sc
