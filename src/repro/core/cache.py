"""Speculation caches: the per-request local cache (paper §3, Figure 2) and the
fleet-scale shared tier in front of it (ROADMAP item 1).

Not exact-match caches: retrieval from the local cache uses the *same scoring
metric* as the knowledge-base retriever, over the (much smaller) set of cached
entries. This gives the paper's rank-preservation property: if the KB top-1
document for a query is present in the cache, cache retrieval returns exactly
that document (proved as a hypothesis property test in
tests/test_cache_properties.py).

DenseRetrievalCache  — keys are embeddings, score = inner product (EDR/ADR/KNN-LM).
SparseRetrievalCache — keys are per-doc term arrays; score = BM25 with the *global*
                       corpus statistics (idf, avgdl) captured at construction, so the
                       cache score of a doc equals its KB score exactly.

Both caches retrieve under the CANONICAL tie order — score descending, then id
ascending — the same contract the retrieval-backend layer
(repro.retrieval.backends) guarantees. Under exact score ties the cache
therefore speculates the very document the KB would verify, instead of wasting
a rollback on an equally-scored neighbor (tests/test_cache_properties.py pins
this against FlatBackend on tie-heavy KBs).

SharedRetrievalCache — the cross-request tier: a thread-safe, in-process LRU
map from *verified queries* to their KB results, shared by every request a
server (or a whole fleet) serves. Lookup is exact-hit on the query bytes
first, then approximate-hit on embedding inner product (dense queries only).
It is strictly a *speculation source*: batched verification still confirms
every emitted document against the KB, so output preservation is untouched —
a shared hit can only save (or waste) a rollback, never change a token.
SharedCacheView is the per-request read-through view RequestState holds:
shared tier first (exact → approximate), this request's own local cache as
the fallback, with the local cache's insert/values_of API passed through
unchanged.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

from repro.retrieval.kb import SparseKB


def query_key(query) -> bytes:
    """Canonical byte key of a verification query: dense embeddings key on
    their float32 bytes, sparse term lists on their int64 bytes. A type tag
    keeps the two families from ever colliding in one shared tier."""
    if isinstance(query, np.ndarray):
        return b"d" + np.ascontiguousarray(query, np.float32).tobytes()
    return b"s" + np.asarray(list(query), np.int64).tobytes()


def _canonical_top(ids_all: np.ndarray, s: np.ndarray, kk: int):
    """Indices of the top-kk entries under the canonical tie order (score
    desc, id asc). The caches score a *slot-compressed* LRU view, so the
    positional tie break the backends use would resolve ties by LRU slot
    order — ties must break on the actual doc ids instead."""
    return np.lexsort((ids_all, -s))[:kk]


class DenseRetrievalCache:
    def __init__(self, d: int, capacity: int = 4096):
        self.capacity = capacity
        self.d = d
        self._keys = np.zeros((capacity, d), np.float32)
        self._ids = np.full((capacity,), -1, np.int64)
        self._values = np.full((capacity,), -1, np.int64)   # optional payload
        self._order: OrderedDict = OrderedDict()            # id -> slot (LRU)
        self._free = list(range(capacity - 1, -1, -1))
        self.size = 0

    def __contains__(self, doc_id) -> bool:
        return int(doc_id) in self._order

    def insert(self, ids, keys, values=None) -> None:
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        keys = np.atleast_2d(np.asarray(keys, np.float32))
        vals = (np.atleast_1d(np.asarray(values, np.int64))
                if values is not None else np.full(len(ids), -1, np.int64))
        for i, did in enumerate(ids):
            did = int(did)
            if did in self._order:                          # refresh LRU + payload
                # a re-insert may carry a fresh key/value (KNN-LM value
                # payloads): leaving the old slot contents behind values_of
                # would serve stale data
                slot = self._order[did]
                self._keys[slot] = keys[i]
                self._values[slot] = vals[i]
                self._order.move_to_end(did)
                continue
            if not self._free:                              # evict LRU
                old, slot = self._order.popitem(last=False)
                self._free.append(slot)
                self.size -= 1
            slot = self._free.pop()
            self._keys[slot] = keys[i]
            self._ids[slot] = did
            self._values[slot] = vals[i]
            self._order[did] = slot
            self.size += 1

    def retrieve(self, query: np.ndarray, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """-> (ids (k,), scores (k,)); ids are -1 if the cache holds < k entries."""
        if self.size == 0:
            return np.full((k,), -1, np.int64), np.full((k,), -np.inf, np.float32)
        slots = np.fromiter(self._order.values(), np.int64, len(self._order))
        ids_all = self._ids[slots]
        s = self._keys[slots] @ np.asarray(query, np.float32)
        kk = min(k, len(slots))
        top = _canonical_top(ids_all, s, kk)
        ids = ids_all[top]
        sc = s[top]
        for did in ids:                                     # LRU touch
            self._order.move_to_end(int(did))
        if kk < k:
            ids = np.pad(ids, (0, k - kk), constant_values=-1)
            sc = np.pad(sc, (0, k - kk), constant_values=-np.inf)
        return ids, sc

    def values_of(self, ids) -> np.ndarray:
        out = []
        for did in np.atleast_1d(ids):
            slot = self._order.get(int(did), None)
            out.append(self._values[slot] if slot is not None else -1)
        return np.asarray(out, np.int64)


class SparseRetrievalCache:
    """BM25-scored cache. Stores per-doc term arrays; corpus stats come from the KB
    (global, fixed) so local scores == KB scores for any cached doc."""

    def __init__(self, kb: SparseKB, capacity: int = 4096):
        self.kb = kb
        self.capacity = capacity
        L = kb.terms.shape[1]
        self._terms = np.full((capacity, L), -1, np.int32)
        self._dl = np.zeros((capacity,), np.float32)
        self._ids = np.full((capacity,), -1, np.int64)
        self._order: OrderedDict = OrderedDict()
        self._free = list(range(capacity - 1, -1, -1))
        self.size = 0

    def __contains__(self, doc_id) -> bool:
        return int(doc_id) in self._order

    def insert(self, ids, keys=None, values=None) -> None:
        for did in np.atleast_1d(np.asarray(ids, np.int64)):
            did = int(did)
            if did in self._order:
                # terms/doc_len are re-read from the (immutable) KB, so unlike
                # the dense cache there is no payload to refresh — LRU only
                self._order.move_to_end(did)
                continue
            if not self._free:
                _, slot = self._order.popitem(last=False)
                self._free.append(slot)
                self.size -= 1
            slot = self._free.pop()
            self._terms[slot] = self.kb.terms[did]
            self._dl[slot] = self.kb.doc_len[did]
            self._ids[slot] = did
            self._order[did] = slot
            self.size += 1

    def retrieve(self, query_terms, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        if self.size == 0:
            return np.full((k,), -1, np.int64), np.full((k,), -np.inf, np.float32)
        slots = np.fromiter(self._order.values(), np.int64, len(self._order))
        ids_all = self._ids[slots]
        T = self._terms[slots]
        dl = self._dl[slots]
        norm = self.kb.k1 * (1 - self.kb.b + self.kb.b * dl / self.kb.avgdl)
        s = np.zeros(len(slots), np.float32)
        for t in query_terms:
            idf = self.kb.idf.get(int(t))
            if idf is None:
                continue
            tf = (T == int(t)).sum(1).astype(np.float32)
            s += idf * tf * (self.kb.k1 + 1) / (tf + norm)
        kk = min(k, len(slots))
        top = _canonical_top(ids_all, s, kk)
        ids = ids_all[top]
        sc = s[top]
        for did in ids:
            self._order.move_to_end(int(did))
        if kk < k:
            ids = np.pad(ids, (0, k - kk), constant_values=-1)
            sc = np.pad(sc, (0, k - kk), constant_values=-np.inf)
        return ids, sc


class SharedRetrievalCache:
    """Fleet-scale shared speculation tier: verified query -> KB result, LRU.

    At fleet scale query distributions are heavy-tailed and identical
    verification queries recur constantly across requests; this tier lets any
    request speculate from any other request's *verified* KB results. Lookup:

      1. exact hit  — the query's canonical bytes (:func:`query_key`) match a
                      stored verified query: return its KB top-k verbatim.
      2. approx hit — (dense only) the query's inner product against a stored
                      query embedding reaches ``approx_threshold``: return
                      that neighbor's result as the speculation. Queries are
                      L2-normalized here, so the threshold is a cosine.

    Results stored here came out of real (batched) verification calls and are
    only ever used to *speculate* — verification still confirms every emitted
    document, so a stale or approximate hit costs at most a rollback and can
    never change served tokens.

    Thread-safe by a single lock around all state: the async fleet's
    verification worker writes results while the main thread's overlapped
    speculation stride reads, and a server object may be shared across
    threads (the folded ``RaLMSpec(persistent_cache=True)`` path). Entries
    are O(k) ids/scores, so the lock hold times are tiny next to a scan.
    """

    def __init__(self, capacity: int = 65536, approx_threshold: float = 0.98,
                 approx: bool = True):
        self.capacity = max(int(capacity), 1)
        self.approx_threshold = float(approx_threshold)
        self.approx = approx
        self._lock = threading.Lock()
        self._order: OrderedDict = OrderedDict()     # key -> slot (LRU)
        self._results: List = [None] * self.capacity  # slot -> (ids, scores)
        self._slot_key: List = [None] * self.capacity  # slot -> key (evict)
        self._free = list(range(self.capacity - 1, -1, -1))
        self._qemb: Optional[np.ndarray] = None       # (capacity, d), lazy
        # stats ledger (read via stats(); guarded by the same lock)
        self.hits_exact = 0
        self.hits_approx = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._order)

    @staticmethod
    def _unit(q: np.ndarray) -> np.ndarray:
        q = np.asarray(q, np.float32)
        n = float(np.linalg.norm(q))
        return q / n if n > 0 else q

    def lookup(self, query) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """-> (ids, scores) copy of a stored verified result, or None. Exact
        byte hit first, then the approximate embedding tier."""
        key = query_key(query)
        dense = isinstance(query, np.ndarray)
        with self._lock:
            slot = self._order.get(key)
            if slot is not None:
                self._order.move_to_end(key)
                self.hits_exact += 1
                ids, sc = self._results[slot]
                return ids.copy(), sc.copy()
            if dense and self.approx and self._qemb is not None and self._order:
                slots = np.fromiter(self._order.values(), np.int64,
                                    len(self._order))
                sims = self._qemb[slots] @ self._unit(query)
                best = int(np.argmax(sims))
                if float(sims[best]) >= self.approx_threshold:
                    bkey = self._slot_key[slots[best]]
                    self._order.move_to_end(bkey)
                    self.hits_approx += 1
                    ids, sc = self._results[slots[best]]
                    return ids.copy(), sc.copy()
            self.misses += 1
            return None

    def put(self, query, ids, scores) -> None:
        """Store a *verified* KB result for ``query``. A duplicate put
        refreshes the stored payload (fresh prefetch depth / KNN values),
        mirroring the local caches' refresh-on-reinsert semantics."""
        key = query_key(query)
        dense = isinstance(query, np.ndarray)
        ids = np.asarray(ids, np.int64).reshape(-1).copy()
        scores = np.asarray(scores, np.float32).reshape(-1).copy()
        with self._lock:
            self.puts += 1
            slot = self._order.get(key)
            if slot is not None:
                self._results[slot] = (ids, scores)
                self._order.move_to_end(key)
                return
            if not self._free:
                old_key, slot = self._order.popitem(last=False)
                self._slot_key[slot] = None
                self._results[slot] = None
                self._free.append(slot)
                self.evictions += 1
            slot = self._free.pop()
            if dense and self.approx:
                q = np.asarray(query, np.float32).reshape(-1)
                if self._qemb is None:
                    self._qemb = np.zeros((self.capacity, q.shape[0]),
                                          np.float32)
                if q.shape[0] == self._qemb.shape[1]:
                    self._qemb[slot] = self._unit(q)
            self._results[slot] = (ids, scores)
            self._slot_key[slot] = key
            self._order[key] = slot

    def stats(self) -> dict:
        with self._lock:
            lookups = self.hits_exact + self.hits_approx + self.misses
            return dict(size=len(self._order), capacity=self.capacity,
                        hits_exact=self.hits_exact,
                        hits_approx=self.hits_approx, misses=self.misses,
                        lookups=lookups, puts=self.puts,
                        evictions=self.evictions,
                        hit_rate=(self.hits_exact + self.hits_approx)
                        / max(lookups, 1))

    def check_invariants(self) -> None:
        """Structural consistency (the concurrent stress test calls this):
        every LRU entry maps to a distinct live slot holding its key and a
        well-formed result; free slots are empty; counts balance."""
        with self._lock:
            slots = list(self._order.values())
            assert len(slots) == len(set(slots)), "slot aliased by two keys"
            assert len(slots) + len(self._free) == self.capacity
            assert len(slots) <= self.capacity
            for key, slot in self._order.items():
                assert self._slot_key[slot] == key
                ids, sc = self._results[slot]
                assert ids.shape == sc.shape and ids.ndim == 1
            for slot in self._free:
                assert self._results[slot] is None
                assert self._slot_key[slot] is None


class SharedCacheView:
    """RequestState's read-through view of the shared tier.

    Exposes the per-request cache API (retrieve / insert / values_of /
    __contains__), so the serving loops drive it exactly like a local cache:

        retrieve: shared tier (exact → approximate) → this request's local
                  cache — the hit path in docs/architecture.md; a full miss
                  speculates cold (did = -1) and verification corrects.
        writes:   pass through to the LOCAL cache only. Shared-tier inserts
                  happen where verified KB results are born (the servers'
                  verification paths), never from per-request doc inserts —
                  the tier maps queries to results, not docs to keys.
    """

    def __init__(self, local, shared: SharedRetrievalCache):
        self.local = local
        self.shared = shared

    @property
    def size(self) -> int:
        return self.local.size

    def __contains__(self, doc_id) -> bool:
        return doc_id in self.local

    def insert(self, ids, keys=None, values=None) -> None:
        self.local.insert(ids, keys, values)

    def values_of(self, ids) -> np.ndarray:
        return self.local.values_of(ids)

    def retrieve(self, query, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        hit = self.shared.lookup(query)
        if hit is None:
            return self.local.retrieve(query, k)
        ids, sc = hit
        kk = min(k, len(ids))
        out_ids = np.full((k,), -1, np.int64)
        out_sc = np.full((k,), -np.inf, np.float32)
        out_ids[:kk] = ids[:kk]
        out_sc[:kk] = sc[:kk]
        return out_ids, out_sc
