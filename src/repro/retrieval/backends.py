"""The retrieval-backend layer: one interface, three execution strategies.

Every dense retriever in this repo ultimately runs the same scan — score a
query batch against the KB embedding matrix, keep the top-k — but *where* that
scan executes is a serving-level decision, not a retriever-level one:

  * :class:`FlatBackend`    — the numpy argpartition scan (single host, BLAS).
  * :class:`KernelBackend`  — the Pallas blocked top-k (`kernels/dense_topk`,
                              interpret mode on CPU, Mosaic on TPU), with the
                              KB embeddings resident on device: uploaded once
                              at construction instead of per call.
  * :class:`ShardedBackend` — the KB sharded across a mesh
                              (`retrieval/sharded.py`): per-shard blocked
                              top-k + ONE all-gather per call, so the fleet's
                              merged verification round is a single collective
                              program however many requests participate.

Each backend offers TWO scans over the same resident KB:

  * :meth:`~DenseSearchBackend.search` — the full scan (EDR / KNN-LM): every
    KB row scored against every query.
  * :meth:`~DenseSearchBackend.search_gathered` — the masked/gathered scan
    (ADR): each query scores only ITS candidate rows, given as a fixed-shape
    padded id matrix (the IVF probe's bucket gather). Pad slots are ``-1``
    and score ``-inf``; the sharded backend scans only the candidates
    resident on each shard, so a fleet round's merged ADR probe is still ONE
    collective (centroid scoring stays host-side in the retriever).

All scans return identical ``(ids, scores)`` under the CANONICAL tie order —
score descending, then id ascending — so the serving layers can swap backends
without perturbing a single served token (tests/test_backends.py asserts
byte-identity across batch sizes, k values, tie-heavy KBs, and KB sizes that
don't divide the shard count). Backends are *pure* scans: no timing, no stats
— the `RetrieverStats` bookkeeping lives in the retriever wrapper
(`retrievers._TimedRetriever`), which consults :meth:`~DenseSearchBackend.cold_shape`
to exclude compile-polluted first calls per shape from the latency-unit
calibration.

Each of the three strategies also has an **int8 quantized** sibling holding
the KB as per-row symmetric int8 codes + fp32 scales (~4x less index memory;
:func:`quantize_kb`):

  * :class:`QuantizedFlatBackend`    (``int8``) — the numpy reference:
    chunked dequant matmul, never materializing a full fp32 KB copy.
  * :class:`QuantizedKernelBackend`  (``int8-kernel``) — the fused Pallas
    dequant+matmul+top-k (`kernels.ops.quant_dense_topk`): only int8 codes
    stream HBM -> VMEM; the cast + scale multiply happen tile-wise on chip.
  * :class:`QuantizedShardedBackend` (``int8-sharded``) — per-shard int8
    residency on the mesh; the dequant multiply rides the same single
    collective per call as the fp32 sharded scan.

Quantized backends are INEXACT: they carry ``exact = False`` and promise a
*recall contract* (recall@k >= 0.95 vs :class:`FlatBackend` across the
property-test KB grid, tests/test_quantized.py) instead of byte-parity. The
three int8 backends share ONE host-side quantization (:func:`quantize_kb`)
and the same score expression ``(q @ codes.T) * scales``, so they remain
byte-comparable with *each other* on grid-quantized inputs, and
speculate+verify through the same inexact backend still byte-matches a
sequential run on that backend (determinism, not exactness, is what the
serving layers need). Every backend reports its resident index footprint as
``kb_bytes``.

Adding a backend (multi-host, quantized index, ...) is a leaf change here plus
a name in :func:`make_backend`; no retriever or server grows a constructor
branch for it.
"""
from __future__ import annotations

import os
import sys
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np


def bootstrap_mesh_shards() -> None:
    """``--mesh-shards N`` needs N host-platform devices, and XLA only reads
    ``xla_force_host_platform_device_count`` before the backend initializes —
    so drivers call this to peek at argv and set the flag BEFORE anything
    imports jax. A no-op when jax is already loaded, when the operator set
    the flag themselves, or when the value isn't a plain int (argparse will
    report that properly once the driver parses for real)."""
    if "jax" in sys.modules:
        return
    n = 0
    argv = sys.argv
    for i, a in enumerate(argv):
        try:
            if a == "--mesh-shards" and i + 1 < len(argv):
                n = int(argv[i + 1])
            elif a.startswith("--mesh-shards="):
                n = int(a.split("=", 1)[1])
        except ValueError:
            return                    # malformed: leave it to argparse
    if n > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}").strip()


@runtime_checkable
class DenseSearchBackend(Protocol):
    """Pure dense top-k scan over a fixed KB embedding matrix."""

    name: str            # CLI spelling (one of BACKENDS)
    calls: int           # completed scans (sharded backends: collectives issued)
    exact: bool          # True: byte-parity with FlatBackend is contractual;
    #                      False: the bounded-recall contract applies instead
    #                      (recall@k >= 0.95 vs FlatBackend + determinism)
    kb_bytes: int        # resident index footprint (codes + scales if int8)

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """queries (B, d) float32 -> (ids (B, k) int64, scores (B, k) float32),
        rows sorted canonically: score desc, ties by id asc."""
        ...

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Masked/gathered scan: query b scores only the KB rows named by
        ``cand[b]`` (the IVF probe's padded bucket gather).

        ``cand`` is (B, C) int64: each row's candidate doc ids, sorted
        ascending, unique, padded with ``-1`` at the END (the retriever
        normalizes probe-order gathers into this form once — with ids in
        column order, every backend's position-stable top-k IS the canonical
        id-asc tie break). Returns ``(ids (B, k'), scores (B, k'))`` with
        ``k' = min(k, C)``, canonically ordered; slots beyond a row's real
        candidate count come back as ``(id=-1, score=-inf)``."""
        ...

    def cold_shape(self, B: int, k: int) -> bool:
        """True iff the NEXT search at this shape pays an XLA compile (and
        records the shape as seen). The compile cache lives on the backend,
        so retrievers sharing one backend agree on what is warm."""
        ...

    def cold_shape_gathered(self, B: int, C: int, k: int) -> bool:
        """`cold_shape` for the gathered scan — its compiled program is also
        shaped by the candidate width ``C``."""
        ...

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        """Peak candidate-buffer bytes ONE ``search_gathered`` call at batch B
        and candidate width C materializes — the gathered-embedding scratch,
        not the resident KB. Kernel/sharded backends route through the fused
        in-kernel gather, so this is a (B, block_c, d) tile independent of C;
        the numpy paths report their row-chunked host scratch. Benchmarks
        record it next to :meth:`pregathered_scratch_bytes` (the (B, C, d)
        tensor the pre-gathered path would build) to track the reduction."""
        ...

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        """What a naive pre-gathered (B, C, d) candidate materialization costs
        at this backend's resident dtype (int8 backends also gather a (B, C)
        fp32 scale row). The baseline `gathered_scratch_bytes` is measured
        against."""
        ...


class _JitShapeMixin:
    """Per-(B, k) compile tracking for jit-backed scans. ``n_rows`` is the
    KB size the backend clamps k against — distinct raw k values that clamp
    to the same compiled program must share one cache entry."""

    def _init_shapes(self, n_rows: int):
        self._shapes = set()
        self._n_rows = n_rows

    def cold_shape(self, B: int, k: int) -> bool:
        key = (B, min(k, self._n_rows))
        if key in self._shapes:
            return False
        self._shapes.add(key)
        return True

    def cold_shape_gathered(self, B: int, C: int, k: int) -> bool:
        key = (B, C, min(k, C))          # 3-tuples: never collide with dense
        if key in self._shapes:
            return False
        self._shapes.add(key)
        return True


def canonical_topk(s: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k of a scored matrix ``s`` (B, N) under the canonical tie order
    (score desc, id asc) — the order ``jax.lax.top_k`` and the Pallas kernel's
    max-extraction loop both produce, so numpy results are comparable
    byte-for-byte with the accelerator backends.

    Vectorized fast path: argpartition for the top-k *set*, candidate ids
    sorted ascending, then a stable sort on score. argpartition picks
    arbitrary members among ties AT the k-th score, so rows where the
    boundary is ambiguous (more ties at the threshold than slots left) are
    re-selected exactly: all ids strictly above the threshold, then the
    lowest ids at it."""
    B, N = s.shape
    k = min(k, N)
    cand = np.argpartition(-s, kth=k - 1, axis=1)[:, :k] if k < N \
        else np.tile(np.arange(N), (B, 1))
    cand = np.sort(cand, axis=1)                      # ties resolve id-asc
    part = np.take_along_axis(s, cand, axis=1)
    thresh = part.min(axis=1)                         # k-th largest per row
    n_gt = (s > thresh[:, None]).sum(axis=1)
    ambiguous = np.nonzero((s == thresh[:, None]).sum(axis=1) > k - n_gt)[0]
    for b in ambiguous:                               # boundary ties: exact fix
        gt = np.nonzero(s[b] > thresh[b])[0]
        eq = np.nonzero(s[b] == thresh[b])[0][:k - gt.size]
        cand[b] = np.concatenate([gt, eq])
        part[b] = s[b, cand[b]]
    order = np.argsort(-part, axis=1, kind="stable")  # stable: keeps id-asc
    ids = np.take_along_axis(cand, order, axis=1).astype(np.int64)
    return ids, np.take_along_axis(part, order, axis=1).astype(np.float32)


def gathered_scores(embeddings: np.ndarray, queries: np.ndarray,
                    cand: np.ndarray) -> np.ndarray:
    """Score each query against ITS candidate rows: ``(B, C)`` float32 with
    pad slots (``cand < 0``) at ``-inf``. Row-chunked so the ``(rows, C, d)``
    gather stays ~64MB — big-KB probes would otherwise materialize GB-scale
    scratch per merged verification call. ``np.matmul`` over a stacked batch
    is per-row deterministic, so chunking cannot change a single bit."""
    B, C = cand.shape
    d = embeddings.shape[1]
    s = np.empty((B, C), np.float32)
    step = max(1, 16_000_000 // max(C * d, 1))
    for i in range(0, B, step):
        emb = embeddings[np.maximum(cand[i:i + step], 0)]
        s[i:i + step] = np.matmul(emb, queries[i:i + step, :, None])[..., 0]
    return np.where(cand >= 0, s, -np.inf)


def quantize_kb(embeddings: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric int8 quantization of a KB embedding matrix:
    ``(N, d) float -> (codes (N, d) int8, scales (N,) float32)`` with
    ``scales = max(|row|) / 127`` (floored at 1e-12 so all-zero rows stay
    finite) and ``codes = clip(rint(row / scale), -127, 127)``.

    Properties the tests pin down (tests/test_quantized.py): scales are
    strictly positive; ``127 * scale`` recovers each row's max-abs to a few
    ulp; dequant error is at most ``scale / 2`` per element; identical rows
    get identical codes+scales. Every int8 backend calls THIS function, so
    the three quantized execution strategies score one and the same code
    matrix."""
    emb = np.asarray(embeddings, np.float32)
    maxabs = np.abs(emb).max(axis=1, initial=0.0)
    scales = (np.maximum(maxabs, np.float32(1e-12))
              / np.float32(127.0)).astype(np.float32)
    codes = np.clip(np.rint(emb / scales[:, None]), -127, 127).astype(np.int8)
    return codes, scales


def quant_scores(codes: np.ndarray, scales: np.ndarray,
                 queries: np.ndarray) -> np.ndarray:
    """Dequantized full scan ``(q @ codes.T) * scales`` -> (B, N) float32.
    The scale multiply lands on the score matrix (a per-row scale is constant
    along d, so ``q . (s*c) == s * (q . c)`` exactly in the reals) — the same
    operation order as the fused kernel and the sharded program. KB-row
    chunked so the fp32 cast of the codes stays ~64MB scratch instead of a
    full fp32 KB copy per call."""
    B, (N, d) = queries.shape[0], codes.shape
    s = np.empty((B, N), np.float32)
    step = max(1, 16_000_000 // max(d, 1))
    for i in range(0, N, step):
        blk = codes[i:i + step].astype(np.float32)
        s[:, i:i + step] = (queries @ blk.T) * scales[None, i:i + step]
    return s


def quant_gathered_scores(codes: np.ndarray, scales: np.ndarray,
                          queries: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """:func:`gathered_scores` over an int8 KB: each query scores ITS
    candidate rows as ``(q . code) * scale``; pad slots (``cand < 0``) at
    ``-inf``. Same ~64MB row chunking as the fp32 path."""
    B, C = cand.shape
    d = codes.shape[1]
    s = np.empty((B, C), np.float32)
    step = max(1, 16_000_000 // max(C * d, 1))
    for i in range(0, B, step):
        idx = np.maximum(cand[i:i + step], 0)
        emb = codes[idx].astype(np.float32)
        s[i:i + step] = (np.matmul(emb, queries[i:i + step, :, None])[..., 0]
                         * scales[idx])
    return np.where(cand >= 0, s, -np.inf)


def _sentinels_to_contract(ids, scores) -> Tuple[np.ndarray, np.ndarray]:
    """Device gathered-scan output -> the search_gathered contract: pad slots
    carry the NEG sentinel on device (kernels/dense_topk.NEG) with id -1;
    the contract (and the numpy path) says (id=-1, score=-inf)."""
    ids = np.asarray(ids, np.int64)
    return ids, np.where(ids < 0, np.float32(-np.inf),
                         np.asarray(scores, np.float32))


class FlatBackend:
    """Single-host numpy scan: one BLAS matmul + canonical argpartition top-k."""

    name = "numpy"
    exact = True

    def __init__(self, embeddings: np.ndarray):
        self.embeddings = embeddings
        self.kb_bytes = embeddings.nbytes
        self.calls = 0

    def cold_shape(self, B: int, k: int) -> bool:
        return False                     # nothing compiles

    def cold_shape_gathered(self, B: int, C: int, k: int) -> bool:
        return False

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        # gathered_scores row-chunks the (rows, C, d) f32 gather to ~64MB
        d = self.embeddings.shape[1]
        step = max(1, 16_000_000 // max(C * d, 1))
        return min(B, step) * C * d * 4

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        return B * C * self.embeddings.shape[1] * 4

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        s = queries @ self.embeddings.T                  # (B, N)
        self.calls += 1
        return canonical_topk(s, k)

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        s = gathered_scores(self.embeddings, queries, cand)
        k2 = min(k, cand.shape[1])
        # cand columns are id-sorted with pads (-inf) last, so a stable sort
        # on score alone IS the canonical order — and pads can never displace
        # real candidates
        order = np.argsort(-s, axis=1, kind="stable")[:, :k2]
        ids = np.take_along_axis(cand, order, axis=1).astype(np.int64)
        self.calls += 1
        return ids, np.take_along_axis(s, order, axis=1).astype(np.float32)


class KernelBackend(_JitShapeMixin):
    """Pallas blocked top-k (`kernels.ops.dense_topk`): KB tiles stream
    HBM -> VMEM, the query block stays MXU-resident. The KB embedding matrix
    is put on device ONCE here — per-call uploads of a multi-GB index would
    dwarf the scan itself. The gathered (ADR) scan routes through the FUSED
    in-kernel gather (`kernels.ops.fused_gathered_topk`): candidate rows DMA
    from the resident KB per (B, block_c, d) tile, so no (B, C, d) tensor
    materializes however wide the probe. ``force_ref=True`` swaps the kernel
    bodies for their jnp oracles (same results — the fused oracle streams the
    same tiles; wall-clock benchmarks use it off-TPU, where interpret-mode
    overhead would swamp the numbers)."""

    name = "kernel"
    exact = True

    def __init__(self, embeddings: np.ndarray, force_ref: bool = False,
                 block_c: Optional[int] = None):
        import jax

        from repro.kernels.dense_topk import FUSED_BLOCK_C
        from repro.kernels.ops import dense_topk, fused_gathered_topk
        self._fn = dense_topk
        self._fn_gathered = fused_gathered_topk
        self._force_ref = force_ref
        self._block_c = block_c or FUSED_BLOCK_C
        self._kb = jax.device_put(np.asarray(embeddings, np.float32))
        self.kb_bytes = self._kb.nbytes
        self.calls = 0
        self._init_shapes(self._kb.shape[0])

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        from repro.kernels.dense_topk import fused_block_c
        return B * fused_block_c(C, self._block_c) * self._kb.shape[1] * 4

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        return B * C * self._kb.shape[1] * 4

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        # same k > N clamp as the other backends: identical (B, min(k, N))
        # results everywhere, and lax.top_k never sees an oversized k
        scores, ids = self._fn(jnp.asarray(queries), self._kb,
                               min(k, self._kb.shape[0]),
                               force_ref=self._force_ref)
        self.calls += 1
        return np.asarray(ids, np.int64), np.asarray(scores, np.float32)

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        scores, ids = self._fn_gathered(jnp.asarray(queries, jnp.float32),
                                        self._kb,
                                        jnp.asarray(cand, jnp.int32),
                                        min(k, cand.shape[1]),
                                        block_c=self._block_c,
                                        force_ref=self._force_ref)
        self.calls += 1
        return _sentinels_to_contract(ids, scores)


class ShardedBackend(_JitShapeMixin):
    """KB sharded over a live mesh: every ``search`` is ONE collective program
    (`sharded_dense_topk`: per-shard scan + all-gather of k candidates per
    shard + replicated global reduce). The KB is padded to a shard multiple
    and placed shard-wise at BUILD time, so per-call work is only the
    replicated query upload; padded rows score ``-inf`` and can never reach
    the global top-k. ``calls`` counts collectives issued — the fleet's
    one-merged-call-per-round invariant is asserted against it.

    The resident representation is a subclass hook (:meth:`_encode`):
    :class:`QuantizedShardedBackend` overrides it to place int8 codes +
    per-row scales shard-wise instead of the fp32 matrix — same program
    structure, same single collective."""

    name = "sharded"
    exact = True

    def __init__(self, embeddings: np.ndarray, n_shards: Optional[int] = None,
                 axis: str = "data", mesh=None,
                 block_c: Optional[int] = None):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.kernels.dense_topk import FUSED_BLOCK_C
        from repro.retrieval.sharded import (sharded_dense_topk,
                                             sharded_gathered_topk)
        self._block_c = block_c or FUSED_BLOCK_C
        if mesh is None:
            devs = jax.devices()
            n = len(devs) if not n_shards else min(n_shards, len(devs))
            mesh = jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))
        self.mesh, self.axis = mesh, axis
        self.n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        self.n_total = embeddings.shape[0]
        shard_n = -(-self.n_total // self.n_shards)
        pad = shard_n * self.n_shards - self.n_total
        matrix, scales = self._encode(embeddings)
        if pad:
            matrix = np.pad(matrix, ((0, pad), (0, 0)))
            if scales is not None:
                scales = np.pad(scales, ((0, pad),))
        self._kb = jax.device_put(jnp.asarray(matrix),
                                  NamedSharding(mesh, P(axis, None)))
        self._scales = None if scales is None else jax.device_put(
            jnp.asarray(scales), NamedSharding(mesh, P(axis)))
        self.kb_bytes = matrix.nbytes + (0 if scales is None else scales.nbytes)
        self.calls = 0
        self._init_shapes(self.n_total)

        import functools

        # `scales` is an ordinary jit argument: None is an empty pytree, so
        # the exact and int8 variants trace to their own programs without a
        # static flag
        @functools.partial(jax.jit, static_argnames=("k",))
        def _scan(q, kb, scales, k):
            return sharded_dense_topk(q, kb, k, self.mesh, axis=self.axis,
                                      n_total=self.n_total, scales=scales)

        @functools.partial(jax.jit, static_argnames=("k",))
        def _scan_gathered(q, kb, scales, cand, k):
            return sharded_gathered_topk(q, kb, cand, k, self.mesh,
                                         axis=self.axis, n_total=self.n_total,
                                         scales=scales,
                                         block_c=self._block_c)

        self._scan = _scan
        self._scan_gathered = _scan_gathered

    def _encode(self, embeddings: np.ndarray):
        """Resident representation: ``(matrix (N, d), per-row scales | None)``."""
        return np.asarray(embeddings, np.float32), None

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        # per-shard peak: the shard program's chunked gather holds one
        # (B, block_c, d) tile (+ a (B, block_c) scale chunk when int8)
        from repro.kernels.dense_topk import fused_block_c
        bc = fused_block_c(C, self._block_c)
        item = self._kb.dtype.itemsize
        return B * bc * (self._kb.shape[1] * item
                         + (4 if self._scales is not None else 0))

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        item = self._kb.dtype.itemsize
        return B * C * (self._kb.shape[1] * item
                        + (4 if self._scales is not None else 0))

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        from repro.retrieval.sharded import mesh_context
        with mesh_context(self.mesh):
            scores, gids = self._scan(jnp.asarray(queries, jnp.float32),
                                      self._kb, self._scales,
                                      min(k, self.n_total))
        self.calls += 1
        return np.asarray(gids, np.int64), np.asarray(scores, np.float32)

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        from repro.retrieval.sharded import mesh_context
        with mesh_context(self.mesh):
            scores, gids = self._scan_gathered(
                jnp.asarray(queries, jnp.float32), self._kb, self._scales,
                jnp.asarray(cand, jnp.int32), min(k, cand.shape[1]))
        self.calls += 1
        return _sentinels_to_contract(gids, scores)


class QuantizedFlatBackend:
    """Single-host numpy scan over the int8 KB: the quantized family's
    reference semantics. Scores are ``(q @ codes.T) * scales`` with the scale
    multiply on the score matrix (the kernel/sharded operation order), then
    the same canonical top-k as :class:`FlatBackend`. Inexact by contract —
    what it promises is recall@k >= 0.95 vs the fp32 scan, not byte-parity."""

    name = "int8"
    exact = False

    def __init__(self, embeddings: np.ndarray):
        self.codes, self.scales = quantize_kb(embeddings)
        self.kb_bytes = self.codes.nbytes + self.scales.nbytes
        self.calls = 0

    def cold_shape(self, B: int, k: int) -> bool:
        return False                     # nothing compiles

    def cold_shape_gathered(self, B: int, C: int, k: int) -> bool:
        return False

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        # quant_gathered_scores casts each row-chunk's codes to f32
        d = self.codes.shape[1]
        step = max(1, 16_000_000 // max(C * d, 1))
        return min(B, step) * C * d * 4

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        return B * C * (self.codes.shape[1] + 4)    # int8 codes + f32 scales

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        s = quant_scores(self.codes, self.scales,
                         np.asarray(queries, np.float32))
        self.calls += 1
        return canonical_topk(s, k)

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        s = quant_gathered_scores(self.codes, self.scales,
                                  np.asarray(queries, np.float32), cand)
        k2 = min(k, cand.shape[1])
        # same argument as FlatBackend: cand columns are id-sorted, pads
        # (-inf) last, so a stable sort on score IS the canonical order
        order = np.argsort(-s, axis=1, kind="stable")[:, :k2]
        ids = np.take_along_axis(cand, order, axis=1).astype(np.int64)
        self.calls += 1
        return ids, np.take_along_axis(s, order, axis=1).astype(np.float32)


class QuantizedKernelBackend(_JitShapeMixin):
    """The fused Pallas dequant+matmul+top-k (`kernels.ops.quant_dense_topk`
    / `quant_fused_gathered_topk`): int8 codes + fp32 row scales are put on
    device ONCE; KB tiles stream HBM -> VMEM as int8 (4x less scan traffic
    than the fp32 kernel) and the cast + scale multiply happen on chip. The
    gathered (ADR) scan uses the fused in-kernel gather — each candidate
    row's codes AND scale DMA per tile, so neither gather materializes at
    probe width. ``force_ref`` routes to the jnp oracles exactly like
    :class:`KernelBackend`."""

    name = "int8-kernel"
    exact = False

    def __init__(self, embeddings: np.ndarray, force_ref: bool = False,
                 block_c: Optional[int] = None):
        import jax

        from repro.kernels.dense_topk import FUSED_BLOCK_C
        from repro.kernels.ops import (quant_dense_topk,
                                       quant_fused_gathered_topk)
        codes, scales = quantize_kb(embeddings)
        self._fn = quant_dense_topk
        self._fn_gathered = quant_fused_gathered_topk
        self._force_ref = force_ref
        self._block_c = block_c or FUSED_BLOCK_C
        self._kb = jax.device_put(codes)
        self._kb_scales = jax.device_put(scales)
        self.kb_bytes = codes.nbytes + scales.nbytes
        self.calls = 0
        self._init_shapes(codes.shape[0])

    def gathered_scratch_bytes(self, B: int, C: int) -> int:
        from repro.kernels.dense_topk import fused_block_c
        bc = fused_block_c(C, self._block_c)
        return B * bc * (self._kb.shape[1] + 4)     # int8 tile + f32 scales

    def pregathered_scratch_bytes(self, B: int, C: int) -> int:
        return B * C * (self._kb.shape[1] + 4)

    def search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        scores, ids = self._fn(jnp.asarray(queries, jnp.float32), self._kb,
                               self._kb_scales, min(k, self._kb.shape[0]),
                               force_ref=self._force_ref)
        self.calls += 1
        return np.asarray(ids, np.int64), np.asarray(scores, np.float32)

    def search_gathered(self, queries: np.ndarray, cand: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        scores, ids = self._fn_gathered(jnp.asarray(queries, jnp.float32),
                                        self._kb, self._kb_scales,
                                        jnp.asarray(cand, jnp.int32),
                                        min(k, cand.shape[1]),
                                        block_c=self._block_c,
                                        force_ref=self._force_ref)
        self.calls += 1
        return _sentinels_to_contract(ids, scores)


class QuantizedShardedBackend(ShardedBackend):
    """Per-shard int8 residency: each device holds its slice of the code
    matrix + row scales, dequantizes into its shard-local score matrix, and
    the program is otherwise the fp32 sharded scan — per-shard top-k, ONE
    all-gather per call, replicated reduce. The fleet's merged verification
    (and ADR's merged probe) through an int8 mesh is still exactly one
    collective per round; ``calls`` keeps counting collectives."""

    name = "int8-sharded"
    exact = False

    def _encode(self, embeddings: np.ndarray):
        return quantize_kb(embeddings)


BACKENDS = ("numpy", "kernel", "sharded", "int8", "int8-kernel",
            "int8-sharded")


def make_backend(name: str, embeddings: np.ndarray, *,
                 n_shards: Optional[int] = None, mesh=None,
                 force_ref: bool = False,
                 block_c: Optional[int] = None) -> DenseSearchBackend:
    """CLI-name -> backend instance (the one constructor branch in the repo).

    ``n_shards``/``mesh`` configure the sharded backends (default: one
    shard per visible device); ``force_ref`` routes the kernel backends
    through the jnp oracle instead of the Pallas body; ``block_c`` overrides
    the fused-gather tile width (kernel/sharded families; default
    `kernels.dense_topk.FUSED_BLOCK_C`)."""
    if name == "numpy":
        return FlatBackend(embeddings)
    if name == "kernel":
        return KernelBackend(embeddings, force_ref=force_ref, block_c=block_c)
    if name == "sharded":
        return ShardedBackend(embeddings, n_shards=n_shards, mesh=mesh,
                              block_c=block_c)
    if name == "int8":
        return QuantizedFlatBackend(embeddings)
    if name == "int8-kernel":
        return QuantizedKernelBackend(embeddings, force_ref=force_ref,
                                      block_c=block_c)
    if name == "int8-sharded":
        return QuantizedShardedBackend(embeddings, n_shards=n_shards,
                                       mesh=mesh, block_c=block_c)
    raise KeyError(f"unknown retrieval backend {name!r}; known: {BACKENDS}")
