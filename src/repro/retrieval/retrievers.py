"""The three retriever classes the paper evaluates.

  * ExactDenseRetriever  (EDR) — brute-force inner product over the flat index.
                                 Scoring is delegated to a pluggable
                                 :mod:`repro.retrieval.backends` object:
                                 'numpy' (flat BLAS scan), 'kernel' (Pallas
                                 blocked top-k, device-resident KB), or
                                 'sharded' (KB sharded over a mesh, one
                                 collective per call) — all byte-identical
                                 under the canonical tie order — plus their
                                 int8 quantized siblings ('int8' /
                                 'int8-kernel' / 'int8-sharded': ~4x less
                                 index memory, deterministic but inexact
                                 under a tested recall@k >= 0.95 contract).
  * IVFRetriever         (ADR) — the TPU-native replacement for DPR-HNSW (DESIGN §3):
                                 k-means coarse quantizer + nprobe cluster scan.
                                 Cheap, less accurate, latency ~ linear in batch with
                                 an intercept — matching the paper's §A.1 measurement.
                                 Centroid scoring stays host-side; the per-bucket
                                 document scan delegates to the same backend layer
                                 as EDR (`search_gathered`: numpy / Pallas kernel /
                                 sharded mesh — one collective per merged probe).
  * BM25Retriever        (SR)  — bag-of-words over the SparseKB.

All retrievers expose:  retrieve(queries, k) -> (ids (B,k) int64, scores (B,k)).
``queries`` is (B, d) embeddings for dense retrievers, a list of term-lists for BM25.

The wall-clock timing + :class:`RetrieverStats` bookkeeping every retriever
needs lives ONCE in :class:`_TimedRetriever`; subclasses implement only the
pure scan (``_search``) and input normalization (``_prep``). Jit-backed
backends additionally get per-shape warmup tracking so one-time XLA compile
cost never pollutes the modeled-latency calibration.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.retrieval.backends import (DenseSearchBackend, canonical_topk,
                                      make_backend)
from repro.retrieval.kb import DenseKB, SparseKB


class RetrieverStats:
    """Per-retriever call ledger (the R component of the paper's G/R decomposition)
    plus a batched-latency MODEL with the paper's §A.1 shape.

    This container has a single CPU core, so a batch-B matmul genuinely costs ~B x
    a GEMV (compute-bound); on the paper's hardware (FAISS on A10 + 15 CPUs) batched
    retrieval is nearly constant-cost for EDR/SR and linear-with-intercept for ADR.
    The model reproduces those shapes, calibrated online from the *measured*
    single-query unit cost, and feeds the benchmarks' 'modeled' timeline — exactly
    the strategy the paper itself uses for async verification under the GIL.
    Wall-clock numbers are always reported alongside.

      EDR/SR: t(B) = unit * (1 + 0.05 * (B - 1))      (near-constant total)
      ADR:    t(B) = unit * (0.55 + 0.45 * B)          (linear, large intercept)

    Calibration hygiene: calls flagged ``warmup=True`` (a jitted backend's
    first call at a given shape — it pays the XLA compile) are counted in the
    call/query/time ledger but EXCLUDED from the ``_unit`` EMA, so the modeled
    timeline and the async overlap gate aren't skewed by compilation cost
    that paper hardware pays once at server start.

    Thread-safe: with async (pipelined) verification the fleet's worker thread
    calls ``add`` while the main thread reads ``model_latency`` for the overlap
    gate and the analytic timeline, so the counters and the ``_unit`` EMA are
    guarded by a (re-entrant: add -> model_latency) lock.
    """

    def __init__(self, kind: str = "const"):
        self.kind = kind
        self.calls = 0
        self.queries = 0
        self.time = 0.0
        self.modeled_time = 0.0
        self.warmup_calls = 0
        # fault-tolerance ledger, recorded by the serving layer's retry shell
        # (_ServerBase._retrieve_guarded): attempts that raised, attempts that
        # overran the per-call deadline, and calls that exhausted the whole
        # retry budget. Successful attempts land in calls/queries as usual;
        # raised attempts never reach add(), so calls counts completed scans.
        self.errors = 0
        self.timeouts = 0
        self.failed_calls = 0
        self._unit: Optional[float] = None
        self._lock = threading.RLock()

    def factor(self, B: int) -> float:
        if self.kind == "linear_intercept":
            return 0.55 + 0.45 * B
        return 1.0 + 0.05 * (B - 1)

    def add(self, n_queries: int, dt: float, warmup: bool = False):
        with self._lock:
            self.calls += 1
            self.queries += n_queries
            self.time += dt
            if warmup:
                # compile-polluted sample: keep it out of the unit calibration
                self.warmup_calls += 1
            # calibrate the unit cost from SINGLE-query calls only — on this
            # 1-core box a batch-B matmul costs ~B x the GEMV, which would
            # pollute the unit
            elif n_queries == 1:
                self._unit = (dt if self._unit is None
                              else 0.8 * self._unit + 0.2 * dt)
            elif self._unit is None:
                self._unit = dt / n_queries    # conservative bootstrap
            self.modeled_time += self.model_latency(n_queries)

    def model_latency(self, B: int) -> float:
        with self._lock:
            return (self._unit or 0.0) * self.factor(B)

    def record_failure(self, kind: str, final: bool = False) -> None:
        """One failed KB-call attempt: ``kind`` is 'timeout' (overran the
        per-call deadline) or 'error' (raised); ``final`` marks the attempt
        that exhausted the retry budget."""
        with self._lock:
            if kind == "timeout":
                self.timeouts += 1
            else:
                self.errors += 1
            if final:
                self.failed_calls += 1


class _TimedRetriever:
    """Shared retrieve() shell: input normalization, wall-clock timing, stats
    ledger, and per-shape warmup detection for jit-backed scans. Subclasses
    provide the pure scan in ``_search`` (and may override ``_prep``); the
    backend objects themselves stay measurement-free."""

    stats: RetrieverStats

    def _prep(self, queries):
        return np.atleast_2d(np.asarray(queries, np.float32))

    def _cold_shape(self, B: int, k: int) -> bool:
        """Will the next scan at this shape pay a one-time compile? Backed
        retrievers delegate to the backend, which owns the jit cache (so
        retrievers sharing a backend agree on what is warm)."""
        return False

    def _search(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def retrieve(self, queries, k: int) -> Tuple[np.ndarray, np.ndarray]:
        queries = self._prep(queries)
        warmup = self._cold_shape(len(queries), k)
        t0 = time.perf_counter()
        ids, scores = self._search(queries, k)
        self.stats.add(len(queries), time.perf_counter() - t0, warmup=warmup)
        return ids, scores


class ExactDenseRetriever(_TimedRetriever):
    """EDR: exact scan, execution strategy chosen by the backend layer.

    ``backend`` is a :mod:`repro.retrieval.backends` name (any of
    ``BACKENDS``, int8 quantized included) or an already-built backend object
    (the serving layer builds ShardedBackend with its mesh knobs);
    ``mesh_shards`` caps the shard count for the sharded backends (0 = one
    shard per visible device)."""

    name = "EDR"

    def __init__(self, kb: DenseKB, backend="numpy", mesh_shards: int = 0):
        self.kb = kb
        self.backend: DenseSearchBackend = (
            backend if not isinstance(backend, str)
            else make_backend(backend, kb.embeddings,
                              n_shards=mesh_shards or None))
        self.stats = RetrieverStats("const")

    def _cold_shape(self, B: int, k: int) -> bool:
        return self.backend.cold_shape(B, k)

    def _search(self, queries, k):
        return self.backend.search(queries, k)

    def keys_of(self, ids) -> np.ndarray:
        return self.kb.embeddings[np.asarray(ids, np.int64)]


class IVFRetriever(_TimedRetriever):
    """ADR: k-means coarse quantizer (host-side centroid scan) + nprobe bucket
    scan, the document scoring of which is delegated to the backend layer —
    the same execution strategies as EDR (int8 quantized included), via
    :meth:`~repro.retrieval.backends.DenseSearchBackend.search_gathered` over
    the fixed-shape padded bucket gather. ``backend`` / ``mesh_shards`` mean
    exactly what they do on :class:`ExactDenseRetriever`; with 'sharded', a
    fleet round's merged ADR probe is ONE collective over the KB shards."""

    name = "ADR"

    def __init__(self, kb: DenseKB, n_clusters: int = 64, nprobe: int = 4,
                 iters: int = 8, seed: int = 3, backend="numpy",
                 mesh_shards: int = 0):
        self.kb = kb
        self.nprobe = nprobe
        self.stats = RetrieverStats("linear_intercept")
        self.backend: DenseSearchBackend = (
            backend if not isinstance(backend, str)
            else make_backend(backend, kb.embeddings,
                              n_shards=mesh_shards or None))
        g = np.random.default_rng(seed)
        X = kb.embeddings
        self.centroids = X[g.choice(X.shape[0], n_clusters, replace=False)].copy()
        for _ in range(iters):                                # Lloyd iterations
            assign = np.argmax(X @ self.centroids.T, axis=1)
            for c in range(n_clusters):
                pts = X[assign == c]
                if len(pts):
                    v = pts.mean(0)
                    self.centroids[c] = v / max(np.linalg.norm(v), 1e-9)
        assign = np.argmax(X @ self.centroids.T, axis=1)
        self.buckets = [np.where(assign == c)[0] for c in range(n_clusters)]
        self._build_pads()

    def _build_pads(self) -> None:
        """Fixed-shape bucket table for the vectorized probe: row c holds
        bucket c's doc ids padded with -1 to the longest bucket, so a batch's
        candidate sets are ONE gather ``_bucket_pad[cs]`` of shape
        (B, nprobe, Lmax) — no per-query Python concatenation."""
        L = max(max((len(bk) for bk in self.buckets), default=1), 1)
        self._bucket_pad = np.full((len(self.buckets), L), -1, np.int64)
        for c, bk in enumerate(self.buckets):
            self._bucket_pad[c, :len(bk)] = bk
        self._bucket_len = np.asarray([len(bk) for bk in self.buckets],
                                      np.int64)

    def _ensure_exec(self) -> None:
        """Backfill execution state on instances restored without __init__
        (benchmarks/common.py rebuilds cached IVF indices via __new__)."""
        if not hasattr(self, "_bucket_pad"):   # caches built pre-vectorization
            self._build_pads()
        if not hasattr(self, "backend"):
            self.backend = make_backend("numpy", self.kb.embeddings)

    def _cand_width(self, k: int) -> int:
        """The fixed candidate width C the gathered scan compiles for:
        nprobe x Lmax from the index, widened to k so fallback/pad slots fit.
        (nprobe clamps to the cluster count, as the probe's argsort slice
        does implicitly.)"""
        nprobe = min(self.nprobe, len(self.buckets))
        return max(self._bucket_pad.shape[1] * nprobe,
                   max(min(k, self.kb.size), 1), k)

    def _cold_shape(self, B: int, k: int) -> bool:
        self._ensure_exec()
        return self.backend.cold_shape_gathered(B, self._cand_width(k), k)

    def _gather_candidates(self, queries: np.ndarray,
                           k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side probe: score centroids, gather the probed buckets' padded
        id rows into the fixed-shape (B, C) candidate matrix, then normalize
        each row to the backend contract — ids sorted ascending, -1 pads last
        (id-sorted columns are what make every backend's positional tie break
        the canonical id-ascending order). Queries whose probes come up empty
        fall back to the first ``min(k, kb.size)`` docs. Returns
        ``(cand, counts)``; counts = real candidates per row."""
        B = queries.shape[0]
        cs = np.argsort(-(queries @ self.centroids.T), axis=1)[:, :self.nprobe]
        cand = self._bucket_pad[cs].reshape(B, -1)        # (B, nprobe*Lmax)
        counts = self._bucket_len[cs].sum(1)              # real cands per row
        F = max(min(k, self.kb.size), 1)
        if cand.shape[1] < max(F, k):                     # room for fallback/pad
            cand = np.pad(cand, ((0, 0), (0, max(F, k) - cand.shape[1])),
                          constant_values=-1)
        empty = counts == 0
        if empty.any():                                   # fallback candidates
            cand[empty] = -1
            cand[empty, :F] = np.arange(F)
            counts = np.where(empty, F, counts)
        big = np.iinfo(np.int64).max
        cand = np.sort(np.where(cand < 0, big, cand), axis=1)
        cand[cand == big] = -1
        return cand, counts

    def _search(self, queries: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized nprobe scan, document scoring on the backend: the padded
        fixed-shape candidate gather goes down to ``backend.search_gathered``
        (numpy chunked matmul / Pallas gathered top-k / one sharded
        collective), which returns the canonical (score desc, id asc) top-k
        over each row's real candidates with (-1, -inf) pads.

        Semantics beyond the backend contract live here: queries whose probes
        come up empty fall back to the first ``min(k, kb.size)`` docs, and
        rows with fewer than k candidates pad by repeating their last real
        (id, score). Because the padded shape is fixed by the index
        (nprobe x Lmax), a batched call is byte-identical to the same queries
        issued one at a time
        (tests/test_retrievers.py::test_batched_equals_sequential)."""
        self._ensure_exec()
        cand, counts = self._gather_candidates(queries, k)
        ids, sc = self.backend.search_gathered(queries, cand, k)
        k2 = ids.shape[1]                                 # min(k, C) == k here
        kk = np.minimum(counts, k2)                       # real hits per row
        fill = np.arange(k2)[None, :] >= kk[:, None]      # pad: repeat last
        last = np.maximum(kk - 1, 0)[:, None]
        ids = np.where(fill, np.take_along_axis(ids, last, axis=1), ids)
        sc = np.where(fill, np.take_along_axis(sc, last, axis=1), sc)
        return ids.astype(np.int64), sc.astype(np.float32)

    def keys_of(self, ids) -> np.ndarray:
        return self.kb.embeddings[np.asarray(ids, np.int64)]


class BM25Retriever(_TimedRetriever):
    name = "SR"

    def __init__(self, kb: SparseKB):
        self.kb = kb
        self.stats = RetrieverStats("const")

    def _prep(self, queries):
        if queries and isinstance(queries[0], (int, np.integer)):
            return [queries]
        return queries

    def _search(self, queries: List[list], k: int) -> Tuple[np.ndarray, np.ndarray]:
        # canonical tie order (score desc, id asc) like the dense backends —
        # the sparse speculation cache retrieves canonically, so under exact
        # BM25 ties both sides name the same doc (no spurious rollback)
        s = np.stack([self.kb.score(q) for q in queries])
        return canonical_topk(s, k)

    def keys_of(self, ids) -> np.ndarray:
        """Sparse 'keys' are the per-doc term arrays."""
        return self.kb.terms[np.asarray(ids, np.int64)]
