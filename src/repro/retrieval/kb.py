"""Knowledge-base containers shared by all retrievers."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.retrieval.encoder import ContextEncoder


@dataclass
class DenseKB:
    """Flat dense index: embeddings (N, d) + doc payloads."""

    embeddings: np.ndarray               # (N, d) float32, unit-norm
    docs: List[list]                     # token lists
    values: Optional[np.ndarray] = None  # per-entry payload (KNN-LM: next token)

    @property
    def size(self) -> int:
        return self.embeddings.shape[0]

    @classmethod
    def build(cls, docs: List[list], encoder: ContextEncoder) -> "DenseKB":
        emb = np.stack([encoder.encode_doc(d) for d in docs])
        return cls(embeddings=emb, docs=docs)


@dataclass
class SparseKB:
    """BM25 bag-of-words index: per-doc term arrays + corpus statistics.

    Term frequencies are computed on the fly against fixed-length term lists —
    TPU/JAX-friendly (no ragged CSR) and exactly reproducible in the local cache,
    which stores the same per-doc term arrays plus the *global* idf/avgdl (the paper's
    requirement that cache scores be computable locally with the same metric)."""

    terms: np.ndarray                    # (N, L) int32 padded with -1
    doc_len: np.ndarray                  # (N,)
    idf: dict                            # term -> idf  (computed once, global)
    avgdl: float
    docs: List[list]
    k1: float = 1.5
    b: float = 0.75

    @property
    def size(self) -> int:
        return self.terms.shape[0]

    @classmethod
    def build(cls, docs: List[list]) -> "SparseKB":
        N = len(docs)
        L = max(len(d) for d in docs)
        terms = np.full((N, L), -1, np.int32)
        dl = np.zeros((N,), np.float32)
        df: dict = {}
        for i, d in enumerate(docs):
            terms[i, :len(d)] = d
            dl[i] = len(d)
            for t in set(d):
                df[t] = df.get(t, 0) + 1
        idf = {t: float(np.log(1 + (N - c + 0.5) / (c + 0.5))) for t, c in df.items()}
        return cls(terms=terms, doc_len=dl, idf=idf, avgdl=float(dl.mean()),
                   docs=docs)

    def score(self, query_terms, sub: Optional[np.ndarray] = None) -> np.ndarray:
        """BM25 scores of ``query_terms`` against all docs (or a subset index).

        Vectorized over the query: repeated terms are deduped and unique
        terms' tf columns come out of batched ``(T[..., None] == terms).sum(1)``
        passes instead of a full (N, L) scan per term — term-chunked so the
        (N, L, chunk) boolean transient stays ~32MB however long the query
        is. Scores are bit-identical to the scalar loop: each unique term's
        BM25 contribution is computed with the same (scalar-idf, float32-tf)
        expression, then accumulated in the original query-occurrence order."""
        T = self.terms if sub is None else self.terms[sub]
        dl = self.doc_len if sub is None else self.doc_len[sub]
        scores = np.zeros(T.shape[0], np.float32)
        known = [int(t) for t in query_terms if int(t) in self.idf]
        if not known:
            return scores
        uniq = list(dict.fromkeys(known))      # dedupe, first-occurrence order
        norm = self.k1 * (1 - self.b + self.b * dl / self.avgdl)
        contrib = {}
        step = max(1, 32_000_000 // max(T.size, 1))
        for i in range(0, len(uniq), step):
            chunk = uniq[i:i + step]
            tf_all = (T[..., None] == np.asarray(chunk, T.dtype)).sum(1) \
                .astype(np.float32)            # (N, chunk): one pass per chunk
            for j, t in enumerate(chunk):
                tf = tf_all[:, j]
                contrib[t] = self.idf[t] * tf * (self.k1 + 1) / (tf + norm)
        for t in known:                        # same accumulation order as the
            scores += contrib[t]               # scalar loop (float-exact)
        return scores


def build_knn_datastore(stream: np.ndarray, encoder: ContextEncoder,
                        context: int = 16, stride: int = 1,
                        limit: Optional[int] = None) -> DenseKB:
    """KNN-LM datastore: key = embedding of leftward context, value = next token.
    Consecutive entries are consecutive training positions — the spatial locality the
    paper's next-n prefetch rule exploits.

    Vectorized: the decayed-window context embedding is a 16-tap FIR over the token
    embeddings, computed as `context` shifted adds over the whole stream — O(N*d)
    instead of a 1-per-entry python loop (needed for the 1M-entry benchmark store).
    """
    stream = np.asarray(stream, np.int64)
    N = len(stream) - context - 1
    idxs = np.arange(0, N, stride)
    if limit:
        idxs = idxs[:limit]
    E = encoder.table[stream]                                  # (len, d)
    S = np.zeros_like(E)
    for j in range(context):                                   # tap j: decay^j
        w = encoder.decay ** j
        # context window of entry i is stream[i : i+context]; last token weight 1
        S[context - 1:] += w * E[context - 1 - j: len(E) - j]
    # entry i's context ends at position i+context-1
    keys = S[idxs + context - 1]
    norms = np.linalg.norm(keys, axis=1, keepdims=True)
    keys = (keys / np.maximum(norms, 1e-9)).astype(np.float32)
    vals = stream[idxs + context].astype(np.int32)
    docs = [stream[i:i + context].tolist() for i in idxs]
    return DenseKB(embeddings=keys, docs=docs, values=vals)
