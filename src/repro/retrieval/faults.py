"""Fault injection for the retrieval path (chaos harness) + the exception
taxonomy the serving layer's fault-tolerance shell is written against.

The serving stack's whole preservation story (byte-identical outputs to
RaLMSeq) rests on the KB verification call being *authoritative* — which is
exactly what makes transient-fault recovery free: KB search is a pure
function of the query (the same invariant `dedup_queries` relies on), so a
retried call returns byte-identical rows, and any schedule of transient
faults on the merged verification call leaves fleet outputs untouched
(tests/test_faults.py proves this per retriever type). This module supplies
the faults; `repro.core.ralmspec._ServerBase._retrieve_guarded` supplies the
retry/deadline shell; `repro.serving.fleet` degrades gracefully when the
budget runs out.

Determinism: the injector draws its fault schedule from a seeded
`numpy.random.Generator`, two uniforms per call *unconditionally*, so the
schedule is a pure function of (seed, call index) — independent of the
configured rates, and identical across two runs with the same seed
(tests/test_faults.py::test_same_seed_same_schedule). Explicit per-call-index
injection (`error_calls` / `spike_calls`) composes with the probabilistic
rates for tests that need a fault to land on one specific call.

Wrappers, not subclasses: `FaultyBackend` decorates any
`repro.retrieval.backends.DenseSearchBackend` (EDR's `search`, ADR's
`search_gathered`), `FaultyKB` decorates a `SparseKB` (BM25's full-corpus
`score`). Everything else — `name`/`calls`/`exact`/`kb_bytes`/`cold_shape*`,
the sparse corpus statistics the speculation caches read — delegates to the
wrapped object, so the wrapped stack is indistinguishable until a fault
fires. The sparse speculation cache scores locally from corpus statistics
(it never calls `SparseKB.score`), so injection hits exactly the KB calls.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import List, Tuple, Union


class TransientRetrievalError(RuntimeError):
    """A retrieval call failed in a way a retry may fix (the injected fault
    kind; real deployments map network/RPC errors here)."""


class RetrievalTimeout(RuntimeError):
    """A retrieval call overran the per-call deadline
    (``RaLMConfig.retrieval_timeout_s``); its rows were discarded."""


class RetrievalFailed(RuntimeError):
    """A retrieval call failed after exhausting the retry budget — the
    serving layer degrades the round (or re-raises when
    ``rcfg.degrade_on_failure`` is off)."""


@dataclass(frozen=True)
class FaultSpec:
    """One seeded fault schedule (see `parse_fault_spec` for the CLI DSL).

    ``p_error`` / ``p_spike`` are per-call probabilities of raising
    :class:`TransientRetrievalError` / sleeping ``spike_s`` seconds before
    the real scan (a spike turns into a timeout when it pushes the call past
    the serving layer's deadline). ``error_calls`` / ``spike_calls`` force a
    fault at explicit 0-based call indices regardless of the draw.
    ``max_faults`` caps the total injected faults (-1 = unlimited) — chaos
    tests use it to make an outage provably transient."""

    seed: int = 0
    p_error: float = 0.0
    p_spike: float = 0.0
    spike_s: float = 0.0
    error_calls: Tuple[int, ...] = ()
    spike_calls: Tuple[int, ...] = ()
    max_faults: int = -1


_FLOAT_KEYS = ("p_error", "p_spike", "spike_s")
_INT_KEYS = ("seed", "max_faults")
_CALL_KEYS = ("error_calls", "spike_calls")


def parse_fault_spec(text: str) -> FaultSpec:
    """Parse the ``--inject-faults`` DSL: comma-separated ``key=value`` with
    keys from :class:`FaultSpec` (call lists are ``;``-separated, e.g.
    ``p_error=0.2,spike_s=0.05,p_spike=0.1,seed=3,error_calls=1;4``).
    Raises ``ValueError`` with a one-line message — the serve CLI maps it to
    an argparse error instead of a traceback."""
    kw = {}
    known = ", ".join(_FLOAT_KEYS + _INT_KEYS + _CALL_KEYS)
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad fault field {part!r} (want key=value; "
                             f"known keys: {known})")
        key, val = part.split("=", 1)
        key = key.strip().replace("-", "_")
        try:
            if key in _FLOAT_KEYS:
                kw[key] = float(val)
            elif key in _INT_KEYS:
                kw[key] = int(val)
            elif key in _CALL_KEYS:
                kw[key] = tuple(int(x) for x in val.split(";") if x.strip())
            else:
                raise ValueError
        except ValueError:
            raise ValueError(f"bad fault field {part!r} (known keys: "
                             f"{known})") from None
    spec = FaultSpec(**kw)
    if not (0.0 <= spec.p_error <= 1.0 and 0.0 <= spec.p_spike <= 1.0):
        raise ValueError("fault probabilities must be in [0, 1]")
    if spec.spike_s < 0:
        raise ValueError("spike_s must be >= 0")
    return spec


class FaultInjector:
    """The seeded schedule executor shared by a stack's fault wrappers.

    ``fire()`` is called once per wrapped KB scan; it decides error / spike /
    clean from the (seed, call index) draw, logs the decision, then acts.
    Thread-safe: the async fleet's verification worker and the main thread
    both reach the wrapped backend (calls are serialized by the serving
    design, but the injector does not rely on that)."""

    def __init__(self, spec: FaultSpec):
        # numpy import deferred to keep this module import-light for the CLI
        import numpy as np
        self.spec = spec
        self._rng = np.random.default_rng(spec.seed)
        self._lock = threading.Lock()
        self.calls = 0
        self.errors = 0
        self.spikes = 0
        self.log: List[Tuple[int, str]] = []   # (call index, 'ok'|'error'|'spike')

    @property
    def injected(self) -> int:
        return self.errors + self.spikes

    def fire(self) -> None:
        spec = self.spec
        with self._lock:
            i, self.calls = self.calls, self.calls + 1
            # draw both uniforms unconditionally: the schedule is a pure
            # function of (seed, call index), whatever the rates are
            u_err, u_spike = self._rng.random(2)
            kind = "ok"
            if spec.max_faults < 0 or self.injected < spec.max_faults:
                if i in spec.error_calls or u_err < spec.p_error:
                    kind = "error"
                    self.errors += 1
                elif i in spec.spike_calls or u_spike < spec.p_spike:
                    kind = "spike"
                    self.spikes += 1
            self.log.append((i, kind))
        if kind == "spike":
            time.sleep(spec.spike_s)
        elif kind == "error":
            raise TransientRetrievalError(f"injected fault at KB call {i}")


Faults = Union[FaultSpec, FaultInjector]


def _injector(faults: Faults) -> FaultInjector:
    return faults if isinstance(faults, FaultInjector) else FaultInjector(faults)


class FaultyBackend:
    """`DenseSearchBackend` decorator: consult the injector, then delegate.
    Capability bits, ledgers and jit-cache state (`name`, `calls`, `exact`,
    `kb_bytes`, `cold_shape*`, shard knobs) pass through to the wrapped
    backend untouched, so every caller that introspects the backend sees the
    real one."""

    def __init__(self, inner, faults: Faults):
        self.inner = inner
        self.injector = _injector(faults)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def search(self, queries, k: int):
        self.injector.fire()
        return self.inner.search(queries, k)

    def search_gathered(self, queries, cand, k: int):
        self.injector.fire()
        return self.inner.search_gathered(queries, cand, k)


class FaultyKB:
    """`SparseKB` decorator for the BM25 path: faults fire on the full-corpus
    ``score`` scan (one draw per query — BM25 scores a merged call's queries
    one by one), corpus statistics delegate untouched."""

    def __init__(self, inner, faults: Faults):
        self.inner = inner
        self.injector = _injector(faults)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def score(self, query_terms, sub=None):
        self.injector.fire()
        return self.inner.score(query_terms, sub)


def inject_faults(retriever, faults: Faults) -> FaultInjector:
    """Wrap a built retriever's KB execution path in the fault harness, in
    place: dense retrievers (EDR/ADR) get their backend wrapped, the sparse
    retriever (SR) its KB. Returns the injector (shared if one was passed)
    so callers can read the schedule log and counters."""
    inj = _injector(faults)
    if hasattr(retriever, "backend"):
        retriever.backend = FaultyBackend(retriever.backend, inj)
    else:
        retriever.kb = FaultyKB(retriever.kb, inj)
    return inj
