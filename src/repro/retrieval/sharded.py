"""Distributed dense retrieval: the knowledge base sharded across the mesh, batched
verification as a single collective program.

This is the multi-chip form of the paper's verification step (DESIGN §3): each
device scans its KB shard with the blocked top-k (the Pallas kernel on TPU; its
jnp oracle under shard_map here), then the per-shard candidates — k << shard size —
are all-gathered and reduced to a global top-k. Collective volume is
O(devices * B * k * 8 bytes): negligible next to the HBM scan, which is the point —
batched verification scales out linearly with chips.

Serving reaches this through :class:`repro.retrieval.backends.ShardedBackend`
(``--retriever-backend sharded``): the fleet's merged verification call per
round is exactly one invocation of :func:`sharded_dense_topk`, i.e. one
collective per round however many requests participate.

KB sizes need not divide the shard count: the KB is padded to a shard multiple
(here, or at build time by ShardedBackend) and the padded rows' scores are
masked to -inf BEFORE the per-shard top-k, so they can neither displace real
candidates within a shard nor reach the global top-k. Results are
byte-identical to the single-host scan under the canonical tie order (score
desc, id asc — `jax.lax.top_k` order per shard; across shards, equal scores
resolve to the lower shard index = lower global id because shard candidates
concatenate in shard order).
"""
from __future__ import annotations

from contextlib import nullcontext
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.dense_topk import NEG  # one pad sentinel, every backend

# jax moved shard_map out of experimental and renamed check_rep -> check_vma;
# support both spellings so the seed toolchain (0.4.x) and current jax run this.
if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:                                        # pragma: no cover - jax>=0.6 path
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def mesh_context(mesh):
    """Context manager activating `mesh` across jax versions (set_mesh /
    use_mesh / no-op — shard_map takes the mesh explicitly anyway)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return nullcontext()


def sharded_dense_topk(queries: jax.Array, kb: jax.Array, k: int, mesh,
                       axis: str = "data", *, n_total: Optional[int] = None,
                       scales: Optional[jax.Array] = None):
    """queries (B, d) replicated; kb (N, d) sharded over `axis`.
    -> (scores (B, k), global ids (B, k)).

    ``n_total`` is the number of REAL KB rows when ``kb`` arrives pre-padded
    to a shard multiple (ShardedBackend pads at build time); rows at global
    ids >= n_total are padding and score -inf. Unpadded non-divisible KBs are
    padded here instead — either way no shard ever misindexes and no padded
    id can reach the global top-k.

    ``scales`` (N,) f32, when given, marks ``kb`` as int8 codes with per-row
    symmetric scales: each shard scores its resident slice as
    ``(q @ codes.T) * scales`` — the dequant multiply lands on the per-shard
    score matrix before the pad mask and per-shard top-k, so only int8 codes
    ever live in shard HBM and the collective shape is unchanged (still ONE
    per call).
    """
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    N = kb.shape[0]
    if n_total is None:
        n_total = N
    shard_n = -(-N // n_shards)
    pad = shard_n * n_shards - N
    if pad:
        kb = jnp.pad(kb, ((0, pad), (0, 0)))
        if scales is not None:
            scales = jnp.pad(scales, ((0, pad),))
    assert k <= n_total, f"top-{k} of a {n_total}-row KB"
    # a shard holds only shard_n rows, so it can contribute at most that many
    # global candidates; n_shards * k_local >= n_total >= k keeps the global
    # reduce exact when k exceeds the shard size
    k_local = min(k, shard_n)

    def local(q, kb_shard, scl_shard):
        kb2 = kb_shard[0] if kb_shard.ndim == 3 else kb_shard
        shard_idx = jax.lax.axis_index(axis)
        s_full = jnp.einsum("bd,nd->bn", q.astype(jnp.float32),
                            kb2.astype(jnp.float32))
        if scl_shard is not None:
            scl2 = scl_shard[0] if scl_shard.ndim == 2 else scl_shard
            s_full = s_full * scl2.astype(jnp.float32)[None, :]
        # mask padded rows BEFORE the per-shard top-k: a zero-padded row
        # scores 0.0, which would displace genuinely negative candidates
        col_gids = shard_idx * shard_n + jnp.arange(shard_n, dtype=jnp.int32)
        s_full = jnp.where(col_gids[None, :] < n_total, s_full, NEG)
        s, ids = jax.lax.top_k(s_full, k_local)
        gids = ids.astype(jnp.int32) + shard_idx * shard_n
        # gather candidates from every shard: (n_shards, B, k_local)
        all_s = jax.lax.all_gather(s, axis)
        all_g = jax.lax.all_gather(gids, axis)
        B = q.shape[0]
        cat_s = jnp.moveaxis(all_s, 0, 1).reshape(B, n_shards * k_local)
        cat_g = jnp.moveaxis(all_g, 0, 1).reshape(B, n_shards * k_local)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_g = jnp.take_along_axis(cat_g, pos, axis=1)
        return top_s, top_g

    # outputs are replicated by construction (all_gather + identical top_k on
    # every shard); the varying-axis inference can't see through axis_index
    if scales is None:
        fn = _shard_map(
            lambda q, kb_shard: local(q, kb_shard, None), mesh=mesh,
            in_specs=(P(), P(axis, None)),
            out_specs=(P(), P()),
            **{_CHECK_KW: False},
        )
        return fn(queries, kb)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(), P()),
        **{_CHECK_KW: False},
    )
    return fn(queries, kb, scales)


def sharded_gathered_topk(queries: jax.Array, kb: jax.Array, cand: jax.Array,
                          k: int, mesh, axis: str = "data", *,
                          n_total: Optional[int] = None,
                          scales: Optional[jax.Array] = None,
                          block_c: Optional[int] = None):
    """The ADR/IVF probe over the sharded KB: queries (B, d) and the padded
    candidate-id matrix cand (B, C) replicated; kb (N, d) sharded over
    ``axis``. -> (scores (B, k), global ids (B, k)); pad slots (-1 in cand,
    or slots beyond a row's real candidate count) surface as (NEG, -1).

    Each shard scores only the candidates RESIDENT in its row range (gather
    from its slice + mask everything else to -inf), takes a per-shard top-k,
    and the candidates all-gather + reduce exactly like the dense scan — so a
    fleet round's merged ADR probe is still ONE collective program. The
    canonical tie order survives because shard s owns the contiguous id range
    [s*shard_n, (s+1)*shard_n): across shards equal scores resolve to the
    lower shard = lower id, and within a shard cand's id-sorted columns make
    lax.top_k's positional tie break id-ascending.

    ``cand`` rows must be id-sorted with -1 pads last and contain no
    duplicate real ids (IVF buckets partition the KB, so probe gathers
    satisfy this by construction). The per-shard gather is TILED: the shard
    program walks ``cand`` in lane-aligned ``block_c`` chunks
    (`kernels.dense_topk.FUSED_BLOCK_C` by default, the same tile width the
    fused kernels use), gathering one (B, block_c, d) slab at a time via
    `lax.map` — peak per-shard candidate scratch is independent of the probe
    width C, and the (B, C) score matrix it builds chunk-wise is a factor d
    smaller. Chunking cannot change a bit: per-candidate dots are computed
    identically and the concatenated chunks reproduce the untiled score
    matrix column-for-column.

    ``scales`` (N,) f32, when given, marks ``kb`` as int8 codes with per-row
    symmetric scales: each shard gathers its resident candidates' codes AND
    row scales chunk-wise, scoring ``(q . codes) * scale`` before the
    residency mask — the probe rides the same single collective over the
    int8-resident mesh."""
    from repro.kernels.dense_topk import FUSED_BLOCK_C, fused_block_c

    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    N = kb.shape[0]
    if n_total is None:
        n_total = N
    shard_n = -(-N // n_shards)
    pad = shard_n * n_shards - N
    if pad:
        kb = jnp.pad(kb, ((0, pad), (0, 0)))
        if scales is not None:
            scales = jnp.pad(scales, ((0, pad),))
    C = cand.shape[1]
    # any single shard may hold ALL of a row's candidates, so the per-shard
    # contribution cannot be divided by n_shards
    k_local = min(k, C)
    # pad the candidate matrix to a tile multiple (-1 = pad -> not owned by
    # any shard -> NEG score, sentinel id; appended columns can't perturb the
    # positional tie break)
    bc = fused_block_c(C, block_c or FUSED_BLOCK_C)
    nbc = -(-C // bc)
    cpad = nbc * bc - C
    if cpad:
        cand = jnp.pad(cand, ((0, 0), (0, cpad)), constant_values=-1)

    def local(q, cd, kb_shard, scl_shard):
        kb2 = kb_shard[0] if kb_shard.ndim == 3 else kb_shard
        shard_idx = jax.lax.axis_index(axis)
        lo = shard_idx * shard_n
        own = (cd >= lo) & (cd < lo + shard_n) & (cd < n_total)
        B = q.shape[0]
        qf = q.astype(jnp.float32)
        scl2 = None
        if scl_shard is not None:
            scl2 = scl_shard[0] if scl_shard.ndim == 2 else scl_shard

        def score_chunk(ch):                   # (B, bc) ids -> (B, bc) f32
            idx = jnp.clip(ch - lo, 0, shard_n - 1)
            emb = jnp.take(kb2, idx, axis=0)   # (B, bc, d): the ONLY gather
            s = jnp.einsum("bcd,bd->bc", emb.astype(jnp.float32), qf)
            if scl2 is not None:
                s = s * jnp.take(scl2, idx, axis=0).astype(jnp.float32)
            return s

        chunks = cd.reshape(B, nbc, bc).transpose(1, 0, 2)
        s = jax.lax.map(score_chunk, chunks)   # sequential: one slab live
        s = s.transpose(1, 0, 2).reshape(B, nbc * bc)
        s = jnp.where(own, s, NEG)
        gids = jnp.where(own, cd, -1)          # non-resident/pad: sentinel id
        s_l, pos = jax.lax.top_k(s, k_local)
        g_l = jnp.take_along_axis(gids, pos, axis=1)
        all_s = jax.lax.all_gather(s_l, axis)  # (n_shards, B, k_local)
        all_g = jax.lax.all_gather(g_l, axis)
        B = q.shape[0]
        cat_s = jnp.moveaxis(all_s, 0, 1).reshape(B, n_shards * k_local)
        cat_g = jnp.moveaxis(all_g, 0, 1).reshape(B, n_shards * k_local)
        top_s, p = jax.lax.top_k(cat_s, k_local)
        top_g = jnp.take_along_axis(cat_g, p, axis=1)
        return top_s, top_g

    if scales is None:
        fn = _shard_map(
            lambda q, cd, kb_shard: local(q, cd, kb_shard, None), mesh=mesh,
            in_specs=(P(), P(), P(axis, None)),
            out_specs=(P(), P()),
            **{_CHECK_KW: False},
        )
        return fn(queries, cand.astype(jnp.int32), kb)
    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(axis, None), P(axis)),
        out_specs=(P(), P()),
        **{_CHECK_KW: False},
    )
    return fn(queries, cand.astype(jnp.int32), kb, scales)


def lower_sharded_retrieval(mesh, *, n_docs: int = 1_048_576, d: int = 256,
                            batch: int = 8, k: int = 20, axis: str = "data"):
    """Dry-run artifact: lower + compile the sharded batched-verification program."""
    q = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    kb = jax.ShapeDtypeStruct((n_docs, d), jnp.float32)
    fn = partial(sharded_dense_topk, k=k, mesh=mesh, axis=axis)
    with mesh_context(mesh):
        lowered = jax.jit(fn).lower(q, kb)
        return lowered.compile()
