"""Context encoder — the DPR stand-in.

A deterministic bag-of-embeddings encoder: fixed (seeded) embedding table, recency-
weighted mean over the last ``window`` tokens, L2-normalized. It plays DPR's role
exactly as the paper's pipeline needs it: a query embedding that drifts smoothly with
the generation context (temporal locality) and matches the document-key space.
"""
from __future__ import annotations

import numpy as np


class ContextEncoder:
    def __init__(self, vocab_size: int, d: int = 64, window: int = 32,
                 decay: float = 0.95, seed: int = 13):
        g = np.random.default_rng(seed)
        self.table = g.standard_normal((vocab_size, d), dtype=np.float32)
        self.table /= np.linalg.norm(self.table, axis=1, keepdims=True)
        self.d = d
        self.window = window
        self.decay = decay

    def encode(self, tokens) -> np.ndarray:
        """tokens: sequence of ints -> (d,) unit vector."""
        toks = np.asarray(tokens, np.int64)[-self.window:]
        if toks.size == 0:
            return np.zeros((self.d,), np.float32)
        w = self.decay ** np.arange(len(toks) - 1, -1, -1, dtype=np.float32)
        v = (self.table[toks] * w[:, None]).sum(0)
        n = np.linalg.norm(v)
        return (v / n).astype(np.float32) if n > 0 else v.astype(np.float32)

    def encode_batch(self, token_seqs) -> np.ndarray:
        return np.stack([self.encode(t) for t in token_seqs])

    def encode_doc(self, tokens) -> np.ndarray:
        """Document key: unweighted normalized mean (order-free, like DPR doc tower)."""
        toks = np.asarray(tokens, np.int64)
        v = self.table[toks].mean(0)
        n = np.linalg.norm(v)
        return (v / n).astype(np.float32) if n > 0 else v.astype(np.float32)
