"""Pallas TPU kernel: blockwise causal (flash) attention for prefill.

The prefill_32k hot spot: O(S^2) attention computed without ever materializing the
S x S score matrix. Grid (batch*kv_head, q_tiles, kv_tiles); the kv dimension is the
innermost (sequential) grid axis so the online-softmax accumulators for one q tile
live in VMEM scratch across kv steps. Causal tiles above the diagonal are skipped
entirely (masked to no-op via pl.when), halving the MXU work like the pure-JAX
blockwise path — but with explicit VMEM tiling: q tile (bq, G, hd), kv tiles
(bk, hd), accumulators (bq, G, hd) f32.

Supports GQA (G = H / KV query heads per kv head), optional sliding window, and an
optional bidirectional prefix (prefix-LM / PaliGemma).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.4e38


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
                    bq: int, bk: int, seq: int, scale: float, causal: bool,
                    window: int, prefix_len: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    # tile coordinates (traced: derived from program ids)
    q_lo = qi * bq
    k_lo = kj * bk
    # causal skip: drop kv tiles entirely in the future of every q row of this
    # tile (bidirectional prefix tiles must NOT be skipped); window skip: drop kv
    # tiles entirely behind the sliding window
    needed = (jnp.logical_or(k_lo <= q_lo + bq - 1, k_lo < prefix_len)
              if causal else jnp.bool_(True))
    in_window = (k_lo + bk > q_lo - window) if window > 0 else jnp.bool_(True)

    @pl.when(jnp.logical_and(needed, in_window))
    def _compute():
        q = q_ref[0]                                 # (bq, G, hd)
        k = k_ref[0]                                 # (bk, hd)
        v = v_ref[0]
        G, hd = q.shape[1], q.shape[2]
        s = jax.lax.dot_general(
            q.astype(jnp.float32).reshape(bq * G, hd),
            k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, G, bk) * scale
        q_idx = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, G, bk), 0)
        k_idx = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, G, bk), 2)
        ok = k_idx < seq
        if causal:
            c = k_idx <= q_idx
            if prefix_len > 0:
                c = jnp.logical_or(
                    c, jnp.logical_and(q_idx < prefix_len, k_idx < prefix_len))
            ok = jnp.logical_and(ok, c)
        if window > 0:
            ok = jnp.logical_and(ok, k_idx > q_idx - window)
        s = jnp.where(ok, s, NEG)

        m_prev = m_sc[...]                           # (bq, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2)
        pv = jax.lax.dot_general(
            p.reshape(bq * G, bk), v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, G, -1)
        acc[...] = acc[...] * corr[..., None] + pv
        m_sc[...] = m_new

    @pl.when(kj == nk - 1)
    def _done():
        o_ref[0] = (acc[...] / jnp.maximum(l_sc[...][..., None], 1e-30)
                    ).astype(o_ref.dtype)


def prefill_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             causal: bool = True, window: int = 0,
                             prefix_len: int = 0, bq: int = 256, bk: int = 256,
                             interpret: bool = False) -> jax.Array:
    """q (B, S, H, hd); k/v (B, S, KV, hd) -> out (B, S, H, hd)."""
    from jax.experimental.pallas import tpu as pltpu

    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    bq = min(bq, S)
    bk = min(bk, S)
    nq, nk = -(-S // bq), -(-S // bk)
    pad_q, pad_k = nq * bq - S, nk * bk - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # layout: fold KV into the leading grid dim: (B*KV, S, G, hd) / (B*KV, S, hd)
    qf = q.reshape(B, nq * bq, KV, G, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(B * KV, nq * bq, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, nk * bk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, nk * bk, hd)

    kernel = functools.partial(
        _prefill_kernel, bq=bq, bk=bk, seq=S, scale=scale, causal=causal,
        window=window, prefix_len=prefix_len)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, G, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, G, hd), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, nq * bq, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, G, hd), jnp.float32),
            pltpu.VMEM((bq, G), jnp.float32),
            pltpu.VMEM((bq, G), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, KV, nq * bq, G, hd).transpose(0, 2, 1, 3, 4) \
             .reshape(B, nq * bq, H, hd)
    return out[:, :S]
