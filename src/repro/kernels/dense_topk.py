"""Pallas TPU kernel: blocked dense retrieval (similarity + streaming top-k).

This is the paper's exact-dense-retriever hot spot, adapted for TPU (DESIGN §3):
FAISS's GPU brute-force scan becomes a single fused kernel that

  * streams KB-embedding tiles (block_n, d) HBM -> VMEM via the BlockSpec pipeline,
  * scores them against the *whole query batch* on the MXU ((B, d) @ (d, block_n) —
    batched verification maps directly onto the B dimension, which is why batching
    is structurally cheap on TPU, cf. paper §A.1),
  * maintains a running top-k per query in VMEM scratch across grid steps using
    K rounds of max-extraction (no lax.top_k inside the kernel — portable and
    MXU/VPU-friendly for the small K regime retrieval lives in).

Grid: one dimension over KB tiles. The query block is small (B ≤ 128 rows padded to
8/128 lanes) and stays resident in VMEM for every grid step.

The GATHERED variant (:func:`gathered_topk_pallas`) is the ADR/IVF form of the
same scan: instead of every KB row, query b scores only its probed buckets'
members, handed in as a pre-gathered (B, C, d) candidate-embedding tensor plus
the (B, C) candidate-id matrix (-1 = padding). Pad slots are masked to the NEG
sentinel before the streaming top-k, so they can never displace a real
candidate; candidate columns arrive id-sorted (the backend contract), which
makes the kernel's first-position tie break the canonical id-ascending order.

The QUANT variants (:func:`quant_topk_pallas`, :func:`quant_gathered_topk_pallas`)
are the int8-KB form of both scans: the KB streams as int8 codes plus a per-row
fp32 scale (symmetric per-row quantization — see
`repro.retrieval.backends.quantize_kb`), and DEQUANT + MATMUL + TOP-K fuse into
one kernel. The int8→f32 cast happens tile-by-tile in VMEM, the scale multiply
lands on the (B, block) score tile, and nothing fp32-sized ever round-trips
through HBM — which is the point: HBM traffic (and KB residency) drop ~4x while
the streaming top-k machinery is byte-for-byte the same `_select_topk`.

The FUSED-GATHER variants (:func:`fused_gathered_topk_pallas`,
:func:`quant_fused_gathered_topk_pallas`) remove the pre-gathered (B, C, d)
tensor entirely: the kernel receives the DEVICE-RESIDENT KB (``pltpu.ANY``
memory space — HBM on TPU) plus the padded candidate-id matrix, and per grid
step DMAs each candidate row of the current ``(block_c,)`` tile from the KB
into a (B, block_c, d) VMEM scratch buffer (double-buffered row copies,
candidate ids read from scalar-prefetch SMEM). Peak candidate-buffer scratch
is B * block_c * d * itemsize — independent of C — where the pre-gathered
path materializes B * C * d in HBM; at C = 4096 with the default
``block_c = 256`` that is a 16x reduction, which is what huge-probe ADR
needs. Scores and the streaming top-k are bit-identical to the pre-gathered
kernel: per-candidate dots don't care whether the row arrived via XLA gather
or per-row DMA, and the merge is the same `_select_topk`. The int8 form DMAs
both the code row and its fp32 scale, so not even the (B, C) scale gather
materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.4e38


def _select_topk(scores, ids, k: int):
    """K rounds of (max, argmax, mask) over axis 1. scores (B, M) f32, ids (B, M).

    An extracted slot's ID is masked to -1 along with its score: once a row
    runs out of real candidates (gathered scans with fewer than k real
    candidates), every further round re-picks an all-NEG position, and it
    must surface as the (-1, NEG) pad sentinel — not echo the id it extracted
    on an earlier grid step."""
    B = scores.shape[0]
    out_s = []
    out_i = []
    for _ in range(k):
        m = jnp.max(scores, axis=1)                       # (B,)
        a = jnp.argmax(scores, axis=1)                    # (B,)
        out_s.append(m)
        out_i.append(jnp.take_along_axis(ids, a[:, None], axis=1)[:, 0])
        picked = (jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
                  == a[:, None])
        scores = jnp.where(picked, NEG, scores)
        ids = jnp.where(picked, -1, ids)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _topk_kernel(q_ref, kb_ref, out_s_ref, out_i_ref, run_s, run_i, *,
                 k: int, block_n: int, n_total: int):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                                        # (B, d)
    kb = kb_ref[...]                                      # (block_n, d)
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (B, block_n)
    base = j * block_n
    ids = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # mask KB padding rows
    s = jnp.where(ids < n_total, s, NEG)
    merged_s = jnp.concatenate([run_s[...], s], axis=1)   # (B, k + block_n)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def _gathered_topk_kernel(q_ref, emb_ref, cand_ref, out_s_ref, out_i_ref,
                          run_s, run_i, *, k: int):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                                        # (B, d)
    emb = emb_ref[...]                                    # (B, block_c, d)
    ids = cand_ref[...]                                   # (B, block_c)
    # per-row batched dot: q[b] . emb[b, c] on the MXU
    s = jax.lax.dot_general(q, emb, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (B, block_c)
    # mask candidate padding (id -1) — pad slots keep id -1 through _select_topk
    s = jnp.where(ids >= 0, s, NEG)
    merged_s = jnp.concatenate([run_s[...], s], axis=1)   # (B, k + block_c)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def gathered_topk_pallas(queries: jax.Array, cand_emb: jax.Array,
                         cand: jax.Array, k: int, *, block_c: int = 512,
                         interpret: bool = False):
    """queries (B, d) f32; cand_emb (B, C, d) f32; cand (B, C) int32 (-1 pad)
    -> (scores (B, k), ids (B, k)); pad slots surface as (NEG, -1)."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    C = cand.shape[1]
    # lane-aligned tile, never tiny: round C up to the 128-lane grid before
    # clamping so a 129..511-wide probe still gets an aligned block
    block_c = max(min(block_c, -(-C // 128) * 128), 128)
    nb = -(-C // block_c)
    pad = nb * block_c - C
    if pad:
        cand_emb = jnp.pad(cand_emb, ((0, 0), (0, pad), (0, 0)))
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)

    kernel = functools.partial(_gathered_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),           # queries resident
            pl.BlockSpec((B, block_c, d), lambda j: (0, j, 0)),  # cand tiles
            pl.BlockSpec((B, block_c), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j: (0, 0)),
            pl.BlockSpec((B, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, cand_emb, cand)


def dense_topk_pallas(queries: jax.Array, kb: jax.Array, k: int, *,
                      block_n: int = 1024, interpret: bool = False):
    """queries (B, d) f32; kb (N, d) f32 -> (scores (B, k), ids (B, k))."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    N = kb.shape[0]
    block_n = max(min(block_n, N), 128)     # MXU-aligned tile, never tiny
    nb = -(-N // block_n)
    pad = nb * block_n - N
    if pad:
        kb = jnp.pad(kb, ((0, pad), (0, 0)))

    kernel = functools.partial(_topk_kernel, k=k, block_n=block_n, n_total=N)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),          # queries resident
            pl.BlockSpec((block_n, d), lambda j: (j, 0)),    # KB tile stream
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j: (0, 0)),
            pl.BlockSpec((B, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, kb)


def _quant_topk_kernel(q_ref, kbq_ref, scale_ref, out_s_ref, out_i_ref,
                       run_s, run_i, *, k: int, block_n: int, n_total: int):
    """Fused dequant + matmul + streaming top-k over an int8 KB tile.

    The tile dequantizes in VMEM (int8 -> f32 cast feeds the MXU matmul) and
    the per-row scale lands on the (B, block_n) SCORE tile — one multiply per
    score instead of one per KB element, algebraically identical because the
    scale is constant along d: q . (s_i * c_i) == s_i * (q . c_i)."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                                        # (B, d) f32
    kbq = kbq_ref[...].astype(jnp.float32)                # (block_n, d) int8
    scl = scale_ref[...]                                  # (1, block_n) f32
    s = jax.lax.dot_general(q, kbq, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (B, block_n)
    s = s * scl                                           # dequant on scores
    base = j * block_n
    ids = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(ids < n_total, s, NEG)                  # mask KB padding rows
    merged_s = jnp.concatenate([run_s[...], s], axis=1)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def _quant_gathered_topk_kernel(q_ref, emb_ref, scl_ref, cand_ref, out_s_ref,
                                out_i_ref, run_s, run_i, *, k: int):
    """Gathered (ADR/IVF) form of the fused dequant scan: per-row batched dot
    over int8 candidate embeddings, candidate-wise scale multiply, pad slots
    (-1 ids) masked to NEG before the streaming top-k."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...]                                        # (B, d)
    emb = emb_ref[...].astype(jnp.float32)                # (B, block_c, d) int8
    scl = scl_ref[...]                                    # (B, block_c)
    ids = cand_ref[...]                                   # (B, block_c)
    s = jax.lax.dot_general(q, emb, (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (B, block_c)
    s = s * scl
    s = jnp.where(ids >= 0, s, NEG)
    merged_s = jnp.concatenate([run_s[...], s], axis=1)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def quant_topk_pallas(queries: jax.Array, kb_q: jax.Array, scales: jax.Array,
                      k: int, *, block_n: int = 1024,
                      interpret: bool = False):
    """queries (B, d) f32; kb_q (N, d) int8; scales (N,) f32
    -> (scores (B, k), ids (B, k)) of the dequantized scan
    ``(q @ kb_q.T) * scales``."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    N = kb_q.shape[0]
    block_n = max(min(block_n, N), 128)     # MXU-aligned tile, never tiny
    nb = -(-N // block_n)
    pad = nb * block_n - N
    if pad:
        kb_q = jnp.pad(kb_q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
    # scales stream as one lane-aligned (1, block_n) row per grid step
    scales = scales.reshape(nb, block_n)

    kernel = functools.partial(_quant_topk_kernel, k=k, block_n=block_n,
                               n_total=N)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),          # queries resident
            pl.BlockSpec((block_n, d), lambda j: (j, 0)),    # int8 tile stream
            pl.BlockSpec((1, block_n), lambda j: (j, 0)),    # row scales
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j: (0, 0)),
            pl.BlockSpec((B, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, kb_q, scales)


def quant_gathered_topk_pallas(queries: jax.Array, cand_emb: jax.Array,
                               cand_scl: jax.Array, cand: jax.Array, k: int, *,
                               block_c: int = 512, interpret: bool = False):
    """queries (B, d) f32; cand_emb (B, C, d) int8; cand_scl (B, C) f32;
    cand (B, C) int32 (-1 pad) -> (scores (B, k), ids (B, k)); pad slots
    surface as (NEG, -1)."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    C = cand.shape[1]
    block_c = max(min(block_c, -(-C // 128) * 128), 128)
    nb = -(-C // block_c)
    pad = nb * block_c - C
    if pad:
        cand_emb = jnp.pad(cand_emb, ((0, 0), (0, pad), (0, 0)))
        cand_scl = jnp.pad(cand_scl, ((0, 0), (0, pad)))
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)

    kernel = functools.partial(_quant_gathered_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),           # queries resident
            pl.BlockSpec((B, block_c, d), lambda j: (0, j, 0)),  # int8 tiles
            pl.BlockSpec((B, block_c), lambda j: (0, j)),     # cand scales
            pl.BlockSpec((B, block_c), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j: (0, 0)),
            pl.BlockSpec((B, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, cand_emb, cand_scl, cand)


# ---------------------------------------------------------------------------
# Fused in-kernel candidate gather: no pre-gathered (B, C, d) tensor.
# ---------------------------------------------------------------------------

FUSED_BLOCK_C = 256     # default gather tile: B * 256 * d * itemsize VMEM


def fused_block_c(C: int, block_c: int = FUSED_BLOCK_C) -> int:
    """The gather tile width a fused call at candidate width C actually uses:
    lane-aligned, never tiny, never wider than C rounded up to the lane grid.
    One definition shared by the kernels, the jnp oracle (so streaming merges
    agree chunk-for-chunk), and the backends' scratch accounting."""
    return max(min(block_c, -(-C // 128) * 128), 128)


def _gather_tile(cand_sref, kb_ref, emb, sem, col0, total, block_c):
    """DMA the current tile's candidate rows KB -> VMEM scratch, double
    buffered: row i+1's copy is in flight while row i's is awaited. Candidate
    ids come from the scalar-prefetch ref (SMEM — scalar reads are free there);
    pad ids (-1) clamp to row 0, fetched-but-masked like the pre-gathered
    path's jnp.take(maximum(cand, 0))."""
    def dma(i, slot):
        b = i // block_c
        c = i - b * block_c
        row = jnp.maximum(cand_sref[b, col0 + c], 0)
        return pltpu.make_async_copy(kb_ref.at[row], emb.at[b, c],
                                     sem.at[slot])

    dma(0, 0).start()

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < total)
        def _next():
            dma(i + 1, 1 - slot).start()

        dma(i, slot).wait()
        return 0

    jax.lax.fori_loop(0, total, body, 0)


def _fused_gathered_kernel(cand_sref, q_ref, ids_ref, kb_ref, out_s_ref,
                           out_i_ref, emb, run_s, run_i, sem, *, k: int):
    """In-kernel gather form of `_gathered_topk_kernel`: same scores, same
    streaming merge, but the (B, block_c, d) candidate tile is DMA'd from the
    resident KB here instead of arriving through the BlockSpec pipeline."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    B, block_c, d = emb.shape
    _gather_tile(cand_sref, kb_ref, emb, sem, j * block_c, B * block_c,
                 block_c)
    q = q_ref[...]                                        # (B, d)
    ids = ids_ref[...]                                    # (B, block_c)
    s = jax.lax.dot_general(q, emb[...], (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # (B, block_c)
    s = jnp.where(ids >= 0, s, NEG)
    merged_s = jnp.concatenate([run_s[...], s], axis=1)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def fused_gathered_topk_pallas(queries: jax.Array, kb: jax.Array,
                               cand: jax.Array, k: int, *,
                               block_c: int = FUSED_BLOCK_C,
                               interpret: bool = False):
    """queries (B, d) f32; kb (N, d) f32 DEVICE-RESIDENT; cand (B, C) int32
    (-1 pad) -> (scores (B, k), ids (B, k)); pad slots surface as (NEG, -1).

    Peak candidate scratch is the B * block_c * d VMEM tile — C never
    materializes. ``cand`` rides twice: as the scalar-prefetch operand (SMEM
    scalar reads drive the row DMAs) and as a blocked VMEM input (vectorized
    pad masking + id merge)."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    C = cand.shape[1]
    block_c = fused_block_c(C, block_c)
    nb = -(-C // block_c)
    pad = nb * block_c - C
    if pad:
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)

    kernel = functools.partial(_fused_gathered_kernel, k=k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j, cand: (0, 0)),     # queries resident
            pl.BlockSpec((B, block_c), lambda j, cand: (0, j)),  # id tiles
            pl.BlockSpec(memory_space=pltpu.ANY),             # resident KB
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j, cand: (0, 0)),
            pl.BlockSpec((B, k), lambda j, cand: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, block_c, d), jnp.float32),         # gather tile
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(cand, queries, cand, kb)


def _quant_fused_gathered_kernel(cand_sref, q_ref, ids_ref, kb_ref, scl_ref,
                                 out_s_ref, out_i_ref, emb, scl, run_s, run_i,
                                 sem_e, sem_s, *, k: int):
    """int8 form of the fused gather: each candidate row DMAs its int8 codes
    AND its fp32 scale element (separate semaphore pair, same double
    buffering), so neither the (B, C, d) codes nor the (B, C) scales ever
    materialize. Dequant lands on the score tile, as in every quant kernel."""
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    B, block_c, d = emb.shape
    col0 = j * block_c
    total = B * block_c

    def dmas(i, slot):
        b = i // block_c
        c = i - b * block_c
        row = jnp.maximum(cand_sref[b, col0 + c], 0)
        return (pltpu.make_async_copy(kb_ref.at[row], emb.at[b, c],
                                      sem_e.at[slot]),
                pltpu.make_async_copy(scl_ref.at[row], scl.at[b, c],
                                      sem_s.at[slot]))

    e0, s0 = dmas(0, 0)
    e0.start()
    s0.start()

    def body(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < total)
        def _next():
            en, sn = dmas(i + 1, 1 - slot)
            en.start()
            sn.start()

        ew, sw = dmas(i, slot)
        ew.wait()
        sw.wait()
        return 0

    jax.lax.fori_loop(0, total, body, 0)
    q = q_ref[...]                                        # (B, d)
    ids = ids_ref[...]                                    # (B, block_c)
    s = jax.lax.dot_general(q, emb[...].astype(jnp.float32),
                            (((1,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    s = s * scl[...]
    s = jnp.where(ids >= 0, s, NEG)
    merged_s = jnp.concatenate([run_s[...], s], axis=1)
    merged_i = jnp.concatenate([run_i[...], ids], axis=1)
    top_s, top_i = _select_topk(merged_s, merged_i, k)
    run_s[...] = top_s
    run_i[...] = top_i

    @pl.when(j == nb - 1)
    def _done():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def quant_fused_gathered_topk_pallas(queries: jax.Array, kb_q: jax.Array,
                                     scales: jax.Array, cand: jax.Array,
                                     k: int, *, block_c: int = FUSED_BLOCK_C,
                                     interpret: bool = False):
    """queries (B, d) f32; kb_q (N, d) int8 + scales (N,) f32 both
    DEVICE-RESIDENT; cand (B, C) int32 (-1 pad) -> (scores (B, k),
    ids (B, k)); pad slots surface as (NEG, -1). Peak candidate scratch is
    B * block_c * (d + 4) bytes."""
    from jax.experimental.pallas import tpu as pltpu

    B, d = queries.shape
    C = cand.shape[1]
    block_c = fused_block_c(C, block_c)
    nb = -(-C // block_c)
    pad = nb * block_c - C
    if pad:
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)

    kernel = functools.partial(_quant_fused_gathered_kernel, k=k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j, cand: (0, 0)),     # queries resident
            pl.BlockSpec((B, block_c), lambda j, cand: (0, j)),  # id tiles
            pl.BlockSpec(memory_space=pltpu.ANY),             # resident codes
            pl.BlockSpec(memory_space=pltpu.ANY),             # resident scales
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j, cand: (0, 0)),
            pl.BlockSpec((B, k), lambda j, cand: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, block_c, d), jnp.int8),            # code tile
            pltpu.VMEM((B, block_c), jnp.float32),            # scale tile
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(cand, queries, cand, kb_q, scales)
