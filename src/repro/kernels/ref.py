"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dense_topk import FUSED_BLOCK_C, NEG, fused_block_c


def dense_topk_ref(queries: jax.Array, kb: jax.Array, k: int):
    """queries (B, d); kb (N, d) -> (scores (B, k), ids (B, k))."""
    s = jnp.einsum("bd,nd->bn", queries.astype(jnp.float32),
                   kb.astype(jnp.float32))
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids.astype(jnp.int32)


def gathered_topk_ref(queries: jax.Array, cand_emb: jax.Array,
                      cand: jax.Array, k: int):
    """queries (B, d); cand_emb (B, C, d); cand (B, C) int32, -1 = padding
    -> (scores (B, k), ids (B, k)); pad slots surface as (NEG sentinel, -1).
    Candidate columns arrive id-sorted, so lax.top_k's first-position tie
    break is the canonical id-ascending order."""
    s = jnp.einsum("bd,bcd->bc", queries.astype(jnp.float32),
                   cand_emb.astype(jnp.float32))
    s = jnp.where(cand >= 0, s, NEG)
    scores, pos = jax.lax.top_k(s, k)
    return scores, jnp.take_along_axis(cand, pos, axis=1).astype(jnp.int32)


def _pad_chunks(cand: jax.Array, block_c: int):
    """(B, C) ids -> (nb, B, bc) id-tile chunks, -1-padded to a bc multiple —
    the same tiling the fused kernels walk, so streaming merges agree
    chunk-for-chunk."""
    B, C = cand.shape
    bc = fused_block_c(C, block_c)
    nb = -(-C // bc)
    pad = nb * bc - C
    if pad:
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)
    return cand.reshape(B, nb, bc).transpose(1, 0, 2)


def _stream_topk(chunks, score_chunk, k: int):
    """Running top-k over id-tile chunks: merge each chunk's scores into a
    (B, k) carry. The carry concatenates BEFORE the chunk, so lax.top_k's
    first-position tie break keeps resolving ties toward earlier columns —
    identical to one top_k over the full width, and to the kernels'
    `_select_topk` merge."""
    B = chunks.shape[1]

    def step(carry, ch):
        run_s, run_i = carry
        s = jnp.where(ch >= 0, score_chunk(ch), NEG)
        merged_s = jnp.concatenate([run_s, s], axis=1)
        merged_i = jnp.concatenate([run_i, ch], axis=1)
        top_s, pos = jax.lax.top_k(merged_s, k)
        return (top_s, jnp.take_along_axis(merged_i, pos, axis=1)), None

    init = (jnp.full((B, k), NEG, jnp.float32),
            jnp.full((B, k), -1, jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, chunks)
    return s, i.astype(jnp.int32)


def fused_gathered_topk_ref(queries: jax.Array, kb: jax.Array,
                            cand: jax.Array, k: int, *,
                            block_c: int = FUSED_BLOCK_C):
    """Streaming oracle for :func:`fused_gathered_topk_pallas`: takes the
    RESIDENT KB (not a pre-gathered tensor) and scans candidate-id tiles with
    a running top-k, so even the oracle's peak candidate scratch is one
    (B, block_c, d) gather — this is what serves under ``force_ref``.
    Bit-identical to :func:`gathered_topk_ref` over jnp.take(kb, cand):
    per-candidate dots are unchanged by chunking over C, and the streaming
    merge preserves the canonical first-position tie break."""
    q = queries.astype(jnp.float32)

    def score_chunk(ch):
        emb = jnp.take(kb, jnp.maximum(ch, 0), axis=0).astype(jnp.float32)
        return jnp.einsum("bd,bcd->bc", q, emb)

    return _stream_topk(_pad_chunks(cand, block_c), score_chunk, k)


def quant_fused_gathered_topk_ref(queries: jax.Array, kb_q: jax.Array,
                                  scales: jax.Array, cand: jax.Array, k: int,
                                  *, block_c: int = FUSED_BLOCK_C):
    """int8 form of :func:`fused_gathered_topk_ref`: codes AND per-row scales
    gather chunk-wise from the resident arrays; the scale multiply lands on
    the score chunk (the kernel operation order)."""
    q = queries.astype(jnp.float32)

    def score_chunk(ch):
        idx = jnp.maximum(ch, 0)
        emb = jnp.take(kb_q, idx, axis=0).astype(jnp.float32)
        s = jnp.einsum("bd,bcd->bc", q, emb)
        return s * jnp.take(scales, idx, axis=0).astype(jnp.float32)

    return _stream_topk(_pad_chunks(cand, block_c), score_chunk, k)


def quant_dense_topk_ref(queries: jax.Array, kb_q: jax.Array,
                         scales: jax.Array, k: int):
    """queries (B, d) f32; kb_q (N, d) int8; scales (N,) f32 -> the dequantized
    scan ``(q @ kb_q.T) * scales``: (scores (B, k), ids (B, k)). The scale
    multiply lands on the score matrix (scale is constant along d), matching
    the fused kernel's operation order bit for bit."""
    s = jnp.einsum("bd,nd->bn", queries.astype(jnp.float32),
                   kb_q.astype(jnp.float32))
    s = s * scales.astype(jnp.float32)[None, :]
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids.astype(jnp.int32)


def quant_gathered_topk_ref(queries: jax.Array, cand_emb: jax.Array,
                            cand_scl: jax.Array, cand: jax.Array, k: int):
    """queries (B, d); cand_emb (B, C, d) int8; cand_scl (B, C) f32;
    cand (B, C) int32, -1 = padding -> (scores (B, k), ids (B, k)); pad slots
    surface as (NEG sentinel, -1)."""
    s = jnp.einsum("bd,bcd->bc", queries.astype(jnp.float32),
                   cand_emb.astype(jnp.float32))
    s = s * cand_scl.astype(jnp.float32)
    s = jnp.where(cand >= 0, s, NEG)
    scores, pos = jax.lax.top_k(s, k)
    return scores, jnp.take_along_axis(cand, pos, axis=1).astype(jnp.int32)


def prefill_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True, window: int = 0,
                          prefix_len: int = 0) -> jax.Array:
    """q (B,S,H,hd); k/v (B,S,KV,hd) -> (B,S,H,hd). Materializes S x S (oracle)."""
    from repro.models.layers import plain_attention
    return plain_attention(q, k, v, causal=causal, window=window,
                           prefix_len=prefix_len)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len: jax.Array) -> jax.Array:
    """q (B, H, hd); caches (B, W, KV, hd); cache_len (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (hd ** 0.5)
    valid = jnp.arange(W)[None] < jnp.asarray(cache_len).reshape(-1, 1)
    s = jnp.where(valid[:, None, None, :], s, -3.4e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
