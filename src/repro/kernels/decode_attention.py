"""Pallas TPU kernel: GQA single-token decode attention over a (ring) KV cache.

The serving-side compute hot spot: one query vector per request attending over a
long KV cache. Adaptation for TPU: flash-decode style — the cache is streamed
(block_w, KV, hd) HBM->VMEM tile by tile, online-softmax accumulators live in VMEM
scratch, invalid ring slots (>= cache_len) are masked. Grid: (batch, cache tiles).

The q/k contraction for one token is a (G, hd) x (hd, block_w) matmul per KV head —
grouped heads give the MXU a real M dimension instead of a degenerate matvec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.4e38


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc, *,
                        block_w: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)

    q = q_ref[0]                                   # (KV, G, hd)
    k = k_ref[0]                                   # (block_w, KV, hd)
    v = v_ref[0]
    cache_len = len_ref[0]

    s = jax.lax.dot_general(
        q.astype(jnp.float32),                     # (KV, G, hd)
        jnp.swapaxes(k, 0, 1).astype(jnp.float32),  # (KV, block_w, hd)
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale  # (KV, G, block_w)

    idx = j * block_w + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(idx < cache_len, s, NEG)

    m_prev = m_sc[...]                             # (KV, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
    p = jnp.exp(s - m_new[..., None])              # (KV, G, block_w)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=2)
    pv = jax.lax.dot_general(
        p, jnp.swapaxes(v, 0, 1).astype(jnp.float32),  # (KV, block_w, hd)
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (KV, G, hd)
    acc[...] = acc[...] * corr[..., None] + pv
    m_sc[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        o_ref[0] = (acc[...] / jnp.maximum(l_sc[...][..., None], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                            cache_len: jax.Array, *, block_w: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q (B, H, hd); k/v_cache (B, W, KV, hd); cache_len (B,) int32
    -> out (B, H, hd)."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    block_w = min(block_w, W)
    nb = -(-W // block_w)
    pad = nb * block_w - W
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, KV, G, hd)
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))

    kernel = functools.partial(_decode_attn_kernel, block_w=block_w, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_w, KV, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_w, KV, hd), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, G, hd), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
