"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel body is
semantically validated; on TPU the same calls compile to Mosaic. ``force_ref=True``
routes to the pure-jnp oracle (used by retrievers when interpret overhead would
dominate a wall-clock benchmark).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.dense_topk import (FUSED_BLOCK_C, dense_topk_pallas,
                                      fused_gathered_topk_pallas,
                                      gathered_topk_pallas,
                                      quant_fused_gathered_topk_pallas,
                                      quant_gathered_topk_pallas,
                                      quant_topk_pallas)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k", "force_ref"))
def dense_topk(queries: jax.Array, kb: jax.Array, k: int,
               force_ref: bool = False):
    """Blocked dense retrieval: (B, d) x (N, d) -> top-k (scores, ids)."""
    if force_ref:
        return ref.dense_topk_ref(queries, kb, k)
    return dense_topk_pallas(queries, kb, k, interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "force_ref"))
def gathered_topk(queries: jax.Array, kb: jax.Array, cand: jax.Array, k: int,
                  force_ref: bool = False):
    """Masked/gathered dense retrieval (the ADR/IVF probe): query b scores
    only the KB rows named by cand[b] ((B, C) int32, -1 = padding). The
    candidate-embedding gather runs on device against the resident KB; pad
    slots come back as (NEG sentinel, -1).

    The gather materializes (B, C, d) in HBM before the kernel streams it
    (unlike the numpy path, which chunks rows to bound host scratch) —
    acceptable while B*C*d stays well under the KB's own footprint. The
    serving path uses :func:`fused_gathered_topk` instead, which tiles the
    gather into the pallas grid; this pre-gathered form stays as the
    small-probe fast path and the fused kernels' parity baseline."""
    emb = jnp.take(kb, jnp.maximum(cand, 0), axis=0)     # (B, C, d)
    if force_ref:
        return ref.gathered_topk_ref(queries, emb, cand, k)
    return gathered_topk_pallas(queries, emb, cand, k, interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "block_c", "force_ref"))
def fused_gathered_topk(queries: jax.Array, kb: jax.Array, cand: jax.Array,
                        k: int, block_c: int = FUSED_BLOCK_C,
                        force_ref: bool = False):
    """The fused-gather ADR/IVF probe: query b scores only the KB rows named
    by cand[b] ((B, C) int32, -1 = padding), and the candidate gather runs
    INSIDE the kernel — each (block_c, d) tile DMAs from the resident KB per
    grid step, so peak candidate scratch is B * block_c * d regardless of C
    (no (B, C, d) materialization anywhere, including under ``force_ref``,
    whose oracle streams the same tiles with a running top-k). Results are
    byte-identical to :func:`gathered_topk`."""
    if force_ref:
        return ref.fused_gathered_topk_ref(queries, kb, cand, k,
                                           block_c=block_c)
    return fused_gathered_topk_pallas(queries, kb, cand, k, block_c=block_c,
                                      interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "force_ref"))
def quant_dense_topk(queries: jax.Array, kb_q: jax.Array, scales: jax.Array,
                     k: int, force_ref: bool = False):
    """Fused dequant + matmul + top-k over an int8 KB: (B, d) x (N, d) int8
    with per-row fp32 scales -> top-k of ``(q @ kb_q.T) * scales``. The KB
    never materializes in fp32 — the cast happens tile-wise in VMEM and the
    scale multiply lands on the score tile."""
    if force_ref:
        return ref.quant_dense_topk_ref(queries, kb_q, scales, k)
    return quant_topk_pallas(queries, kb_q, scales, k, interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "force_ref"))
def quant_gathered_topk(queries: jax.Array, kb_q: jax.Array,
                        scales: jax.Array, cand: jax.Array, k: int,
                        force_ref: bool = False):
    """Masked/gathered fused dequant scan (the ADR/IVF probe over an int8 KB):
    query b scores only the rows named by cand[b] ((B, C) int32, -1 = pad).
    The candidate gather pulls int8 codes + fp32 row scales — 4x less HBM
    traffic than the fp32 gather; pad slots come back as (NEG sentinel, -1)."""
    emb = jnp.take(kb_q, jnp.maximum(cand, 0), axis=0)    # (B, C, d) int8
    scl = jnp.take(scales, jnp.maximum(cand, 0), axis=0)  # (B, C) f32
    if force_ref:
        return ref.quant_gathered_topk_ref(queries, emb, scl, cand, k)
    return quant_gathered_topk_pallas(queries, emb, scl, cand, k,
                                      interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "block_c", "force_ref"))
def quant_fused_gathered_topk(queries: jax.Array, kb_q: jax.Array,
                              scales: jax.Array, cand: jax.Array, k: int,
                              block_c: int = FUSED_BLOCK_C,
                              force_ref: bool = False):
    """Fused-gather form of :func:`quant_gathered_topk`: each candidate row's
    int8 codes AND fp32 scale DMA from the resident arrays inside the kernel
    — neither the (B, C, d) code gather nor the (B, C) scale gather
    materializes; peak candidate scratch is B * block_c * (d + 4) bytes.
    Byte-identical to :func:`quant_gathered_topk`."""
    if force_ref:
        return ref.quant_fused_gathered_topk_ref(queries, kb_q, scales, cand,
                                                 k, block_c=block_c)
    return quant_fused_gathered_topk_pallas(queries, kb_q, scales, cand, k,
                                            block_c=block_c,
                                            interpret=_interpret())


@partial(jax.jit, static_argnames=("force_ref",))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, force_ref: bool = False):
    """Flash-decode GQA attention over a ring KV cache."""
    if force_ref:
        return ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
    return decode_attention_pallas(q, k_cache, v_cache, cache_len,
                                   interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "prefix_len", "force_ref"))
def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0, prefix_len: int = 0,
                      force_ref: bool = False):
    """Blockwise (flash) causal attention for prefill — never materializes S x S."""
    from repro.kernels.prefill_attention import prefill_attention_pallas
    if force_ref:
        return ref.prefill_attention_ref(q, k, v, causal=causal, window=window,
                                         prefix_len=prefix_len)
    return prefill_attention_pallas(q, k, v, causal=causal, window=window,
                                    prefix_len=prefix_len, interpret=_interpret())
