"""Production meshes.

A function, not a module-level constant — importing this module never touches jax
device state. The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
*before* any jax import; everything else sees the real (1-device CPU) platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist (CPU tests: 1 device) as a degenerate (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
