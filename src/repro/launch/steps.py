"""Step functions + ShapeDtypeStruct input specs for every (arch x shape) pair.

``make_step(arch, shape, mesh)`` returns (fn, arg_structs, in_shardings) such that

    jax.jit(fn, in_shardings=in_shardings).lower(*arg_structs).compile()

is the multi-pod dry-run for that pair. No arrays are ever allocated — params,
optimizer state and decode caches are all ShapeDtypeStructs (weak-type-correct).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import LONG_CONTEXT_WINDOW, get_config, get_shape
from repro.configs.base import InputShape, ModelConfig
from repro.distributed.sharding import (batch_axes, data_specs, param_specs,
                                        state_specs)
from repro.models.model import build_model
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.trainer import make_train_step


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _extra_structs(cfg: ModelConfig, B: int, dtype) -> dict:
    ex = {}
    if cfg.family == "audio":
        ex["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_frames, cfg.d_model), dtype)
    if cfg.family == "vlm":
        ex["patches"] = jax.ShapeDtypeStruct((B, cfg.vision_patches, cfg.d_model), dtype)
    return ex


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config tweaks: dispatch chunking for MoE at scale; the sliding
    window for the sub-quadratic long-context variant is applied via the decode
    cache width (W), not the config."""
    if cfg.moe is not None:
        # keep the (E, C, d) dispatch buffer bounded: ~8k tokens per chunk globally
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_chunk=8192))
    return cfg


def microbatches_for(shape: InputShape, mesh) -> int:
    if shape.kind != "train":
        return 1
    rows_per_shard = shape.global_batch
    for a in batch_axes(mesh):
        rows_per_shard //= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    # target ~1 sequence per data-shard per microbatch
    return max(1, min(shape.global_batch, rows_per_shard))


def make_step(arch: str, shape_name: str, mesh, *, dtype=jnp.bfloat16,
              num_microbatches: Optional[int] = None, kv_shard: str = "window",
              fsdp: bool = True, tp: bool = True,
              dispatch_chunk: Optional[int] = None):
    cfg = adapt_config(get_config(arch), get_shape(shape_name))
    if dispatch_chunk and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_chunk=dispatch_chunk))
    shape = get_shape(shape_name)
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len

    params_s = jax.eval_shape(lambda k: model.init(k, dtype), jax.random.PRNGKey(0))
    pspec = param_specs(params_s, mesh, fsdp=fsdp, tp=tp)
    psh = _named(mesh, pspec)

    if shape.kind == "train":
        extra = _extra_structs(cfg, B, dtype)
        S_text = S - (cfg.vision_patches if cfg.family == "vlm" else 0)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
            **extra,
        }
        opt_s = jax.eval_shape(init_adamw, params_s)
        ospec = param_specs(opt_s, mesh, fsdp=fsdp, tp=tp)
        nm = num_microbatches or microbatches_for(shape, mesh)
        step = make_train_step(model, AdamWConfig(), remat=True,
                               num_microbatches=nm)
        args = (params_s, opt_s, batch)
        shardings = (psh, _named(mesh, ospec), _named(mesh, data_specs(batch, mesh)))
        return step, args, shardings

    if shape.kind == "prefill":
        extra = _extra_structs(cfg, B, dtype)
        S_text = S - (cfg.vision_patches if cfg.family == "vlm" else 0)
        tokens = jax.ShapeDtypeStruct((B, S_text), jnp.int32)

        def prefill_step(params, tokens, extra):
            logits, aux = model.forward(params, tokens,
                                        extra=extra or None, last_only=True)
            return logits

        tok_spec = data_specs({"tokens": tokens}, mesh)["tokens"]
        ex_spec = data_specs(extra, mesh)
        args = (params_s, tokens, extra)
        shardings = (psh, NamedSharding(mesh, tok_spec), _named(mesh, ex_spec))
        return prefill_step, args, shardings

    # decode: ONE new token against a cache of seq_len (ring window for 500k)
    W = LONG_CONTEXT_WINDOW if shape.seq_len > 100_000 else shape.seq_len
    state_s = jax.eval_shape(
        lambda: model.init_decode_state_stacked(B, W, dtype))
    sspec = state_specs(state_s, mesh, B, kv_shard=kv_shard)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, state, token, pos):
        return model.decode_step_stacked(params, state, token, pos)

    tok_spec = data_specs({"t": token}, mesh)["t"]
    args = (params_s, state_s, token, pos)
    shardings = (psh, _named(mesh, sspec), NamedSharding(mesh, tok_spec),
                 NamedSharding(mesh, P()))
    return decode_step, args, shardings
