"""Multi-pod dry-run: lower + compile every (architecture x input shape) pair on the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh, capturing memory_analysis,
cost_analysis and the collective-byte census for the roofline (EXPERIMENTS §Dry-run).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]

The XLA_FLAGS lines below run before ANY jax import (device count locks on first
init); only the module docstring precedes them.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|f8\w*)\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
          "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype.split("[")[0][:4].rstrip("["), 2)


def collective_census(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the (optimized) HLO."""
    census = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+\s*=\s*(.+?)\s*(\w[\w\-]*)\(", ls)
        if not m:
            continue
        op = m.group(2)
        base = op.rstrip("0123456789.-")
        for c in _COLLECTIVES:
            if base == c or base == c + "-start" or base == c.replace("-", "_"):
                shapes = _SHAPE_RE.findall(m.group(1))
                b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
                census[c]["count"] += 1
                census[c]["bytes"] += b
    census["total_bytes"] = sum(v["bytes"] for v in census.values()
                                if isinstance(v, dict))
    return census


def dryrun_pair(arch: str, shape: str, *, multi_pod: bool = False,
                verbose: bool = True, **overrides) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    donate = overrides.pop("donate", False)
    fn, args, shardings = make_step(arch, shape, mesh, **overrides)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    # donate the mutable step state (train: params+opt; decode: caches) — standard
    # production buffer aliasing; exercised as a §Perf iteration
    dn = ()
    if donate:
        dn = (0, 1) if shape in ("train_4k",) else ((1,) if "decode" in shape or shape == "long_500k" else ())
    try:
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=dn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        census = collective_census(hlo)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", -1)) if cost else -1,
            bytes_accessed=float(cost.get("bytes accessed", -1)) if cost else -1,
            collectives=census,
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
                output_bytes=getattr(mem, "output_size_in_bytes", 0),
                temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
                generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", 0),
            ),
        )
        if verbose:
            print(f"[OK] {arch} x {shape} ({rec['mesh']}) "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"flops {rec['flops']:.3g} coll {census['total_bytes']:.3g}B")
            print("     memory:", rec["memory"])
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a finding, not a crash
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} x {shape} ({rec['mesh']}): {rec['error']}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                results.append(dryrun_pair(a, s, multi_pod=mp))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} pairs compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
