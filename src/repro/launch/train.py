"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 200 --batch 8 --seq 128

Runs on the local mesh (CPU here, the production mesh on real hardware), synthetic
LM data, AdamW, periodic checkpoints. With --reduced it trains the smoke-scale
variant of the arch family (the ~100M-class end-to-end run of deliverable (b) uses
--arch knnlm-247m without --reduced).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.training.checkpoint import save_checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.trainer import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    opt_state = init_adamw(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    step_fn = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)

    def add_extra(b):
        if cfg.family == "audio":
            b["frames"] = np.zeros((args.batch, cfg.encoder_frames, cfg.d_model),
                                   np.float32)
        if cfg.family == "vlm":
            b["patches"] = np.zeros((args.batch, cfg.vision_patches, cfg.d_model),
                                    np.float32)
        return b

    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = add_extra(data.batch(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == 1:
            m = jax.device_get(metrics)
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0)/step:.2f}s/step)")
        if args.ckpt_dir and step % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step, params, opt_state)
            print(f"  checkpoint -> {path}")
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
