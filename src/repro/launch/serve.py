"""End-to-end RaLM serving driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.serve --retriever edr --mode both \
        --requests 5 --variant psa

Builds the synthetic Wikipedia-like corpus, the chosen retriever, a reduced GPT-2-
class host LM, and serves QA-style requests with RaLMSeq (baseline) and/or RaLMSpec,
printing the paper-style G/R latency decomposition and the speed-up ratio.

``--concurrency N`` (N > 1) switches the speculative path to the fleet: a
BatchedServeEngine with N slots and a FleetServer that serves requests in groups
of N, merging every slot's verification queries into one batched KB call per
round (cross-request batched verification). Outputs stay identical to the
sequential baseline; the driver checks this when --mode both.

``--scheduler continuous`` serves through ContinuousFleetServer instead of
fixed groups: requests sit on an arrival timeline and are admitted into engine
slots the moment slots free up mid-flight (continuous batching). Arrivals are
Poisson at ``--arrival-rate`` requests per modeled second (0 = everything
arrives at t=0, the saturated regime) or trace-driven via ``--arrival-trace
"0,0.5,1.2,..."``. ``--num-requests`` sets the request count (alias:
``--requests``). Example:

    PYTHONPATH=src python -m repro.launch.serve --scheduler continuous \
        --concurrency 4 --num-requests 12 --arrival-rate 2

``--async-fleet`` pipelines the fleet rounds (either scheduler): the merged
verification KB call runs on a worker thread while the fleet speculates the
next lockstep stride, with per-slot carry/invalidation — the paper's +A,
fleet-wide. A variant containing 'a' implies it.

Fault tolerance (fleet paths): ``--retry-max`` / ``--retry-backoff`` /
``--retrieval-timeout`` configure the retry-with-backoff + per-call-deadline
shell around the merged verification KB call (retried calls return
byte-identical rows — KB search is deterministic — so recovery preserves
outputs); ``--inject-faults 'p_error=0.2,p_spike=0.1,spike_s=0.05,seed=3'``
wraps the retriever's KB path in the seeded chaos harness
(repro.retrieval.faults); ``--max-queue-depth`` / ``--queue-deadline`` bound
the continuous scheduler's admission queue, shedding overflow/expired
requests with a ``shed`` status instead of queueing unboundedly:

    PYTHONPATH=src python -m repro.launch.serve --mode spec --concurrency 2 \
        --requests 4 --inject-faults p_error=0.2,seed=3 --retry-max 3

``--retriever-backend {numpy,kernel,sharded,int8,int8-kernel,int8-sharded}``
picks the dense retrievers' execution backend (`repro.retrieval.backends`):
the flat numpy scan, the Pallas blocked top-k (`kernels/dense_topk`,
interpret mode on CPU, Mosaic on TPU; KB resident on device), the
mesh-sharded scan (`retrieval/sharded.py`) where every merged verification
round is ONE collective over the KB shards — or their int8 quantized
siblings, which hold the KB as per-row symmetric int8 codes + fp32 scales
(~4x less index memory; INEXACT: a tested recall@k >= 0.95 contract instead
of byte-parity, see docs/architecture.md). EDR delegates its full scan
(``search``); ADR delegates its IVF bucket scan (``search_gathered`` —
centroid scoring stays host-side, so the merged ADR probe is still one
collective on the sharded backends, fp32 and int8 alike). SR has a single
execution strategy (see ``BACKEND_SUPPORT``). ``--mesh-shards N`` sets the
shard count — on a CPU host it forces an N-device host platform (XLA_FLAGS,
applied below before jax initializes), simulating the multi-chip layout the
sharded backends target:

    PYTHONPATH=src python -m repro.launch.serve --concurrency 4 \
        --retriever-backend sharded --mesh-shards 4 --requests 4

    PYTHONPATH=src python -m repro.launch.serve --retriever adr \
        --retriever-backend sharded --mesh-shards 4 --concurrency 4 --requests 4

    PYTHONPATH=src python -m repro.launch.serve --concurrency 4 \
        --retriever-backend int8-sharded --mesh-shards 4 --requests 4
"""
from __future__ import annotations

# --mesh-shards N must force the N-device host platform BEFORE jax loads;
# repro.retrieval.backends is jax-free at import time, so this is safe here
from repro.retrieval.backends import BACKENDS, bootstrap_mesh_shards

bootstrap_mesh_shards()

import argparse  # noqa: E402
import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.cache import SharedRetrievalCache
from repro.core.knnlm import KNNLMSeq, KNNLMSpec
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.faults import inject_faults, parse_fault_spec
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.continuous import ContinuousFleetServer, as_requests
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.serving.workload import Workload, default_workload
from repro.training.data import make_queries, synthetic_corpus

WORKLOADS = ("ralm", "knnlm")
SCHEDULERS = ("seq", "single", "fixed", "continuous")

# The ONE capability table the CLI validation, the drivers, the benchmarks and
# the docs all mean: (workload, retriever) -> supported execution backends.
# Every listed cell runs under every scheduler in SCHEDULERS. EDR delegates
# its full scan and ADR its IVF bucket scan to `repro.retrieval.backends`
# (fp32 and int8 quantized strategies alike); SR's BM25 term scan has a
# single (numpy) execution strategy. KNN-LM has no SR cell: its datastore
# must carry per-entry next-token values, which a BM25 SparseKB does not.
CAPABILITIES = {
    ("ralm", "edr"): BACKENDS,
    ("ralm", "adr"): BACKENDS,
    ("ralm", "sr"): ("numpy",),
    ("knnlm", "edr"): BACKENDS,
    ("knnlm", "adr"): BACKENDS,
}

# per-retriever view of the table under the default (ralm) workload — kept
# because docs/tests reference backend support by retriever alone
BACKEND_SUPPORT = {r: CAPABILITIES[("ralm", r)] for r in ("edr", "adr", "sr")}


def validate_stack(workload: str, retriever: str, backend: str = "numpy",
                   scheduler: str = "fixed") -> None:
    """THE error path for serving-stack capability: every rejection —
    unknown workload/scheduler, workload x retriever, retriever x backend —
    raises ValueError here, naming the valid set. ``build_stack`` calls it
    before building anything; the CLI maps the message to ``argparse.error``."""
    if workload not in WORKLOADS:
        raise ValueError(f"unknown workload {workload!r} "
                         f"(supported: {', '.join(WORKLOADS)})")
    if scheduler not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {scheduler!r} "
                         f"(supported: {', '.join(SCHEDULERS)})")
    if (workload, retriever) not in CAPABILITIES:
        sup = [r for (w, r) in CAPABILITIES if w == workload]
        raise ValueError(
            f"workload {workload!r} does not support retriever {retriever!r} "
            f"(supported: {', '.join(sup)})")
    sup = CAPABILITIES[(workload, retriever)]
    if backend not in sup:
        raise ValueError(
            f"retriever {retriever!r} does not support backend {backend!r} "
            f"(supported: {', '.join(sup)})")


@dataclasses.dataclass
class ServeStack:
    """Everything the serving drivers and benchmarks need, by name — the
    typed return of :func:`build_stack` (replacing the old positional
    6-tuple) and the one argument :func:`make_server` takes."""

    cfg: object
    model: object
    params: object
    docs: list
    encoder: ContextEncoder
    retriever: object
    rcfg: RaLMConfig
    workload: Workload
    retriever_kind: str = "edr"        # capability-table key ("edr"/"adr"/"sr")
    backend: str = "numpy"             # retrieval execution backend
    shared_cache: object = None        # optional SharedRetrievalCache tier
    stream: object = None              # KNN-LM token stream (None for ralm)
    engine: object = None              # cached by make_server; pass your own
                                       # to share one across servers


def build_stack(retriever: str, *, n_docs: int = 20000, arch: str = "ralm-gpt2-medium",
                backend: str = "numpy", mesh_shards: int = 0, seed: int = 0,
                enc_dim: int = 64, d_model: int = 256, workload: str = "ralm",
                rcfg: RaLMConfig = None, shared_cache=None,
                knn_entries: int = 20000) -> ServeStack:
    """Model + corpus + retriever + workload for the serving drivers and
    benchmarks, validated against the capability table and returned as a
    :class:`ServeStack`. ``backend`` picks the dense retrievers' execution
    backend (`repro.retrieval.backends.BACKENDS`, fp32 or int8 quantized —
    EDR's full scan and ADR's IVF bucket scan alike); ``mesh_shards`` caps
    the sharded backends' shard count (0 = one shard per visible device);
    ``enc_dim``/``d_model`` let benchmarks tune the retrieval-vs-LM cost
    ratio (bench_async_fleet needs retrieval-heavy EDR).

    With ``workload='knnlm'`` the KB is a (context -> next token) datastore
    over the corpus token stream (``knn_entries`` caps its size; the stream
    is returned on the stack for prompt construction) and the retriever runs
    over the datastore embeddings — same EDR/ADR/backends, different rows."""
    validate_stack(workload, retriever, backend)
    if rcfg is None:
        rcfg = RaLMConfig(knnlm=(workload == "knnlm"))
    else:
        rcfg = dataclasses.replace(rcfg, knnlm=(workload == "knnlm"))
    cfg = reduced(get_config(arch), layers=2, d_model=d_model)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    docs = synthetic_corpus(n_docs, cfg.vocab_size)
    stream = None
    if workload == "knnlm":
        stream = np.concatenate([np.asarray(d, np.int32) for d in docs])
        enc = ContextEncoder(cfg.vocab_size, d=enc_dim, window=16)
        kb = build_knn_datastore(stream, enc, context=16, limit=knn_entries)
    else:
        enc = ContextEncoder(cfg.vocab_size, d=enc_dim)
        kb = (SparseKB.build(docs) if retriever == "sr"
              else DenseKB.build(docs, enc))
    if retriever == "sr":
        retr = BM25Retriever(kb)
    else:
        retr = (ExactDenseRetriever(kb, backend=backend,
                                    mesh_shards=mesh_shards)
                if retriever == "edr" else
                IVFRetriever(kb, backend=backend, mesh_shards=mesh_shards))
    return ServeStack(cfg=cfg, model=model, params=params, docs=docs,
                      encoder=enc, retriever=retr, rcfg=rcfg,
                      workload=default_workload(rcfg),
                      retriever_kind=retriever, backend=backend,
                      shared_cache=shared_cache, stream=stream)


def make_server(stack: ServeStack, *, scheduler: str = "fixed",
                n_slots: int = 1, cache_window: int = 512,
                async_fleet=None, engine=None):
    """THE server factory: every driver/benchmark server comes from here.

    ``scheduler`` picks the serving shape — ``seq`` (the per-request
    sequential baseline), ``single`` (single-request speculation),
    ``fixed`` (FleetServer lockstep groups of ``n_slots``), ``continuous``
    (ContinuousFleetServer admitting mid-flight) — and the stack's workload
    picks the algorithm (RaLM or KNN-LM) within it. ``async_fleet`` is the
    fleet servers' ``async_rounds`` (None follows rcfg.async_verification).
    Engines are cached on ``stack.engine`` and reused when the type and slot
    count match, so seq/single (or repeated fleet builds at one width) share
    one set of compiled decode functions; pass ``engine=`` to override."""
    validate_stack(stack.workload.name, stack.retriever_kind, stack.backend,
                   scheduler)
    knn = stack.workload.name == "knnlm"
    if scheduler in ("seq", "single"):
        eng = engine if engine is not None else stack.engine
        if not isinstance(eng, ServeEngine):
            eng = ServeEngine(stack.model, stack.params,
                              cache_window=cache_window)
            stack.engine = eng
        if scheduler == "seq":
            cls = KNNLMSeq if knn else RaLMSeq
            return cls(eng, stack.retriever, stack.rcfg, stack.encoder)
        if knn:
            return KNNLMSpec(eng, stack.retriever, stack.rcfg, stack.encoder)
        return RaLMSpec(eng, stack.retriever, stack.rcfg, stack.encoder,
                        shared_cache=stack.shared_cache)
    beng = engine if engine is not None else stack.engine
    if not (isinstance(beng, BatchedServeEngine) and beng.n_slots == n_slots):
        beng = BatchedServeEngine(stack.model, stack.params, n_slots,
                                  cache_window=cache_window)
        stack.engine = beng
    cls = ContinuousFleetServer if scheduler == "continuous" else FleetServer
    return cls(beng, stack.retriever, stack.rcfg, stack.encoder,
               async_rounds=async_fleet, shared_cache=stack.shared_cache,
               workload=stack.workload)


def variant_config(variant: str, base: RaLMConfig) -> RaLMConfig:
    """'', 'p', 's', 'a', 'ps', 'sa', 'pa', 'psa' — paper Table 1/4 naming."""
    return dataclasses.replace(
        base,
        prefetch_top_k=20 if "p" in variant else 1,
        use_os3="s" in variant,
        async_verification="a" in variant,
    )


def make_arrivals(n: int, rate: float, trace: str = "", seed: int = 0):
    """Arrival times on the modeled clock: a trace beats a rate beats all-at-0.

    ``trace`` is comma-separated seconds, or ``@path`` naming a file with one
    arrival time per line (blank lines and ``#`` comments ignored); either
    form is cycled/truncated to n. ``rate`` > 0 draws Poisson arrivals
    (exponential inter-arrival gaps, rate req/s). Malformed traces raise
    ``ValueError`` with a one-line message — the CLI maps it to an argparse
    error instead of a traceback."""
    if trace:
        text = trace
        if trace.startswith("@"):
            path = trace[1:]
            try:
                with open(path) as fh:
                    text = ",".join(line.split("#", 1)[0] for line in fh)
            except OSError as e:
                raise ValueError(
                    f"cannot read arrival trace file {path!r}: {e}") from None
        pts = []
        for x in text.replace("\n", ",").split(","):
            x = x.strip()
            if not x:
                continue
            try:
                pts.append(float(x))
            except ValueError:
                raise ValueError(f"malformed arrival time {x!r} "
                                 "(want seconds as a float)") from None
        if not pts:
            raise ValueError("arrival trace is empty")
        if any(p < 0 for p in pts):
            raise ValueError("arrival times must be >= 0")
        return [pts[i % len(pts)] for i in range(n)]
    if rate > 0:
        gaps = np.random.default_rng(seed).exponential(1.0 / rate, size=n)
        return np.cumsum(gaps).tolist()
    return [0.0] * n


def main() -> None:
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--workload", choices=list(WORKLOADS), default="ralm",
                    help="ralm: iterative RaLM (Algorithm 1, byte-parity); "
                         "knnlm: KNN-LM serving (per-token datastore "
                         "retrieval, token-match parity — paper §5.3)")
    ap.add_argument("--retriever", choices=["edr", "adr", "sr"], default="edr")
    ap.add_argument("--mode", choices=["seq", "spec", "both"], default="both")
    ap.add_argument("--variant", default="psa",
                    help="subset of 'psa': prefetch / OS3 scheduler / async")
    ap.add_argument("--requests", "--num-requests", dest="requests", type=int,
                    default=5, help="number of requests to serve")
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--concurrency", type=int, default=1,
                    help=">1: serve the speculative path through the fleet "
                         "(batched engine + cross-request batched verification)")
    ap.add_argument("--scheduler", choices=["fixed", "continuous"],
                    default="fixed",
                    help="fixed: groups of --concurrency in lockstep; "
                         "continuous: admit into freed slots mid-flight")
    ap.add_argument("--async-fleet", action="store_true",
                    help="pipeline fleet rounds: overlap the merged "
                         "verification KB call with the next lockstep "
                         "speculation stride (per-slot carry, adaptive gate; "
                         "implied by a variant containing 'a')")
    ap.add_argument("--retriever-backend",
                    choices=list(BACKENDS), default="numpy",
                    help="dense scoring backend (EDR full scan / ADR bucket "
                         "scan): numpy, the Pallas top-k kernel (interpret "
                         "mode on CPU), the mesh-sharded scan (one "
                         "collective per merged verification round), or "
                         "their int8 quantized siblings int8/int8-kernel/"
                         "int8-sharded (~4x less index memory, recall@k "
                         "contract instead of byte-parity). SR supports "
                         "numpy only")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard count for the sharded backends "
                         "(0 = one shard per visible device; on CPU, N > 1 "
                         "forces an N-device host platform before jax "
                         "initializes)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrival rate, requests per modeled second "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--arrival-trace", default="",
                    help="comma-separated arrival times in modeled seconds, "
                         "or @FILE with one arrival per line "
                         "(overrides --arrival-rate)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for Poisson arrivals")
    ap.add_argument("--shared-cache", action="store_true",
                    help="put a fleet-scale shared speculation cache tier in "
                         "front of the KB (exact-hit on query bytes, then "
                         "approximate-hit on embedding inner product); "
                         "speculation-only, so outputs stay byte-identical "
                         "to the baseline")
    ap.add_argument("--shared-cache-capacity", type=int, default=65536,
                    help="entries held by the shared cache tier (LRU)")
    ap.add_argument("--retry-max", type=int, default=2,
                    help="KB-call retries (after the first attempt) on the "
                         "fleet verification/seed paths; a call failing every "
                         "attempt degrades its round to speculation-only")
    ap.add_argument("--retry-backoff", type=float, default=0.0,
                    help="base exponential backoff in seconds between KB-call "
                         "retries (retry i sleeps base*2^(i-1))")
    ap.add_argument("--retrieval-timeout", type=float, default=0.0,
                    help="per-KB-call deadline in seconds (0 = none): an "
                         "overrunning call is discarded and retried — safe "
                         "because KB search is deterministic")
    ap.add_argument("--inject-faults", default="",
                    help="chaos harness: seeded fault schedule for the KB "
                         "path, e.g. 'p_error=0.2,p_spike=0.1,spike_s=0.05,"
                         "seed=3' (also error_calls/spike_calls=i;j;..., "
                         "max_faults=n; see repro.retrieval.faults). "
                         "Requires --mode spec on a fleet scheduler")
    ap.add_argument("--max-queue-depth", type=int, default=0,
                    help="continuous scheduler: arrived requests allowed to "
                         "wait for a slot before newest arrivals are shed "
                         "(0 = unbounded)")
    ap.add_argument("--queue-deadline", type=float, default=0.0,
                    help="continuous scheduler: queueing-delay deadline in "
                         "modeled seconds past which a waiting request is "
                         "shed (0 = none)")
    args = ap.parse_args()
    try:
        # fail loudly rather than silently measuring the wrong scan: the ONE
        # capability table (and its one error path) names the valid set
        validate_stack(args.workload, args.retriever, args.retriever_backend,
                       args.scheduler)
    except ValueError as e:
        ap.error(str(e))
    arrivals = None
    if args.scheduler == "continuous":
        # parse the arrival trace BEFORE building the stack: a malformed
        # trace or unreadable @file is a usage error, not a traceback
        try:
            arrivals = make_arrivals(args.requests, args.arrival_rate,
                                     args.arrival_trace, args.seed)
        except ValueError as e:
            ap.error(f"--arrival-trace: {e}")
    fault_spec = None
    if args.inject_faults:
        try:
            fault_spec = parse_fault_spec(args.inject_faults)
        except ValueError as e:
            ap.error(f"--inject-faults: {e}")
        # fault tolerance lives on the fleet serving paths: the RaLMSeq
        # baseline and the single-request RaLMSpec path have no retry /
        # degradation shell, so injecting faults there would just crash —
        # reject the combination loudly instead
        if args.mode != "spec":
            ap.error("--inject-faults requires --mode spec (the RaLMSeq "
                     "baseline has no fault-tolerance shell)")
        if args.scheduler != "continuous" and args.concurrency <= 1:
            ap.error("--inject-faults requires a fleet scheduler: use "
                     "--concurrency > 1 or --scheduler continuous (the "
                     "single-request path has no fault-tolerance shell)")

    rcfg = variant_config(args.variant.replace("-", ""),
                          RaLMConfig(max_new_tokens=args.max_new,
                                     speculation_stride=args.stride,
                                     retry_max=args.retry_max,
                                     retry_backoff_s=args.retry_backoff,
                                     retrieval_timeout_s=args.retrieval_timeout,
                                     max_queue_depth=args.max_queue_depth,
                                     queue_deadline_s=args.queue_deadline))
    shared = (SharedRetrievalCache(capacity=args.shared_cache_capacity)
              if args.shared_cache else None)
    stack = build_stack(
        args.retriever, n_docs=args.n_docs, backend=args.retriever_backend,
        mesh_shards=args.mesh_shards, workload=args.workload, rcfg=rcfg,
        shared_cache=shared)
    docs, retr = stack.docs, stack.retriever
    if args.retriever_backend != "numpy":
        b = retr.backend
        detail = (f"{b.n_shards} shard(s), one collective per KB call"
                  if b.name.endswith("sharded") else
                  "device-resident KB" if b.name.endswith("kernel") else
                  "int8 codes + fp32 row scales, numpy scan")
        if not b.exact:
            detail += (f"; INEXACT (recall contract), index "
                       f"{b.kb_bytes / 1e6:.1f} MB int8")
        print(f"{args.retriever.upper()} backend: {b.name} ({detail})")
    inj = inject_faults(retr, fault_spec) if fault_spec is not None else None
    if args.workload == "knnlm":
        # KNN-LM prompts are prefixes of the datastore's own token stream —
        # the regime where neighbour retrieval carries signal
        prompts = [stack.stream[i * 97:i * 97 + 48].tolist()
                   for i in range(args.requests)]
    else:
        prompts = [(q * 12)[:48] for q in make_queries(docs, args.requests)]

    def run(server, label):
        tot_w = tot_g = tot_r = 0.0
        toks = []
        for p in prompts:
            r = server.serve(p)
            tot_w += r.wall_time
            tot_g += r.gen_time
            tot_r += r.retrieval_time
            toks.append(r.tokens)
        print(f"{label:14s} wall {tot_w:7.2f}s  G {tot_g:6.2f}s  R {tot_r:6.2f}s")
        return tot_w, toks

    async_rounds = True if args.async_fleet else None  # None: follow variant

    def degradation_line(res) -> None:
        """One line of fault-tolerance accounting when anything fired."""
        if not (res.kb_errors or res.kb_timeouts or res.kb_failures
                or res.degraded_rounds or res.worker_crashes
                or res.seed_failures or getattr(res, "shed", 0)):
            return
        print(f"{'fault ledger':14s} retried {res.kb_errors} errors + "
              f"{res.kb_timeouts} timeouts; {res.kb_failures} calls failed "
              f"for good -> {res.degraded_rounds} degraded rounds "
              f"({res.degraded_requests} requests), {res.worker_crashes} "
              f"worker crashes recovered, {res.seed_failures} seed calls "
              f"lost, {getattr(res, 'shed', 0)} requests shed")

    def run_fleet(label):
        tot_w = tot_an = 0.0
        toks, n_tok = [], 0
        # context manager: the async verification worker is released even if
        # a serve() raises mid-group
        with make_server(stack, scheduler="fixed",
                         n_slots=args.concurrency,
                         async_fleet=async_rounds) as fleet:
            for i in range(0, len(prompts), args.concurrency):
                fr = fleet.serve(prompts[i:i + args.concurrency])
                tot_w += fr.wall_time
                tot_an += fr.analytic_time
                n_tok += fr.total_tokens
                toks.extend(r.tokens for r in fr.results)
                degradation_line(fr)
        print(f"{label:14s} wall {tot_w:7.2f}s  modeled {tot_an:6.2f}s  "
              f"throughput {n_tok / max(tot_an, 1e-9):8.1f} tok/s (modeled)")
        return tot_w, toks

    def run_continuous(label):
        with make_server(stack, scheduler="continuous",
                         n_slots=args.concurrency,
                         async_fleet=async_rounds) as server:
            cr = server.serve(as_requests(prompts, arrivals))
        print(f"{label:14s} wall {cr.wall_time:7.2f}s  "
              f"modeled makespan {cr.analytic_time:6.2f}s  "
              f"throughput {cr.throughput():8.1f} tok/s (modeled)  "
              f"p50 {cr.p50:.2f}s  p99 {cr.p99:.2f}s  "
              f"peak live {cr.max_live}")
        degradation_line(cr)
        return cr.wall_time, [r.tokens for r in cr.results]

    knn = args.workload == "knnlm"
    results = {}
    if args.mode in ("seq", "both"):
        results["seq"] = run(make_server(stack, scheduler="seq"),
                             "KNNLMSeq" if knn else "RaLMSeq")
    if args.mode in ("spec", "both"):
        base = "KNNLMSpec" if knn else "RaLMSpec"
        label = base + ("+" + args.variant.upper() if args.variant else "")
        if args.scheduler == "continuous":
            results["spec"] = run_continuous(f"Continuous x{args.concurrency}")
        elif args.concurrency > 1:
            results["spec"] = run_fleet(f"Fleet x{args.concurrency}")
        else:
            results["spec"] = run(make_server(stack, scheduler="single"),
                                  label)
    if len(results) == 2:
        same = all(a == b for a, b in zip(results["seq"][1], results["spec"][1]))
        kind = ("outputs token-match" if stack.workload.equivalence ==
                "token-match" else "outputs identical")
        print(f"{kind}: {same}   "
              f"speed-up {results['seq'][0] / max(results['spec'][0], 1e-9):.2f}x")
    if getattr(getattr(retr, "backend", None), "name", "").endswith("sharded"):
        # the merge invariant, visible: every KB call (seed or merged
        # verification round — EDR scan or ADR probe) executed as exactly one
        # sharded collective
        print(f"sharded collectives: {retr.backend.calls}  "
              f"KB calls: {retr.stats.calls}  (1 collective per call)")
    if shared is not None:
        st = shared.stats()
        print(f"shared cache: {st['hits_exact']} exact + "
              f"{st['hits_approx']} approx hits / {st['lookups']} lookups "
              f"({st['hit_rate']:.0%} hit rate), {st['size']} entries")
    if inj is not None:
        print(f"fault injection: {inj.errors} errors + {inj.spikes} spikes "
              f"over {inj.calls} KB scans (seed {inj.spec.seed}); "
              f"retried {retr.stats.errors + retr.stats.timeouts} attempts, "
              f"{retr.stats.failed_calls} calls failed after retries")


if __name__ == "__main__":
    main()
