"""End-to-end RaLM serving driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.serve --retriever edr --mode both \
        --requests 5 --variant psa

Builds the synthetic Wikipedia-like corpus, the chosen retriever, a reduced GPT-2-
class host LM, and serves QA-style requests with RaLMSeq (baseline) and/or RaLMSpec,
printing the paper-style G/R latency decomposition and the speed-up ratio.

``--concurrency N`` (N > 1) switches the speculative path to the fleet: a
BatchedServeEngine with N slots and a FleetServer that serves requests in groups
of N, merging every slot's verification queries into one batched KB call per
round (cross-request batched verification). Outputs stay identical to the
sequential baseline; the driver checks this when --mode both.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import RaLMConfig, get_config, reduced
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.models.model import build_model
from repro.retrieval.encoder import ContextEncoder
from repro.retrieval.kb import DenseKB, SparseKB
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine
from repro.serving.engine import ServeEngine
from repro.serving.fleet import FleetServer
from repro.training.data import make_queries, synthetic_corpus


def build_stack(retriever: str, *, n_docs: int = 20000, arch: str = "ralm-gpt2-medium",
                backend: str = "numpy", seed: int = 0):
    cfg = reduced(get_config(arch), layers=2, d_model=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    docs = synthetic_corpus(n_docs, cfg.vocab_size)
    enc = ContextEncoder(cfg.vocab_size, d=64)
    if retriever == "sr":
        kb = SparseKB.build(docs)
        retr = BM25Retriever(kb)
    else:
        kb = DenseKB.build(docs, enc)
        retr = (ExactDenseRetriever(kb, backend=backend) if retriever == "edr"
                else IVFRetriever(kb))
    return cfg, model, params, docs, enc, retr


def variant_config(variant: str, base: RaLMConfig) -> RaLMConfig:
    """'', 'p', 's', 'a', 'ps', 'sa', 'pa', 'psa' — paper Table 1/4 naming."""
    return dataclasses.replace(
        base,
        prefetch_top_k=20 if "p" in variant else 1,
        use_os3="s" in variant,
        async_verification="a" in variant,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", choices=["edr", "adr", "sr"], default="edr")
    ap.add_argument("--mode", choices=["seq", "spec", "both"], default="both")
    ap.add_argument("--variant", default="psa",
                    help="subset of 'psa': prefetch / OS3 scheduler / async")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--concurrency", type=int, default=1,
                    help=">1: serve the speculative path through the fleet "
                         "(batched engine + cross-request batched verification)")
    args = ap.parse_args()

    cfg, model, params, docs, enc, retr = build_stack(
        args.retriever, n_docs=args.n_docs)
    rcfg = variant_config(args.variant.replace("-", ""),
                          RaLMConfig(max_new_tokens=args.max_new,
                                     speculation_stride=args.stride))
    prompts = [(q * 12)[:48] for q in make_queries(docs, args.requests)]
    eng = ServeEngine(model, params, cache_window=512)

    def run(server, label):
        tot_w = tot_g = tot_r = 0.0
        toks = []
        for p in prompts:
            r = server.serve(p)
            tot_w += r.wall_time
            tot_g += r.gen_time
            tot_r += r.retrieval_time
            toks.append(r.tokens)
        print(f"{label:14s} wall {tot_w:7.2f}s  G {tot_g:6.2f}s  R {tot_r:6.2f}s")
        return tot_w, toks

    def run_fleet(label):
        beng = BatchedServeEngine(model, params, args.concurrency,
                                  cache_window=512)
        fleet = FleetServer(beng, retr, rcfg, enc)
        tot_w = tot_an = 0.0
        toks, n_tok = [], 0
        for i in range(0, len(prompts), args.concurrency):
            fr = fleet.serve(prompts[i:i + args.concurrency])
            tot_w += fr.wall_time
            tot_an += fr.analytic_time
            n_tok += fr.total_tokens
            toks.extend(r.tokens for r in fr.results)
        print(f"{label:14s} wall {tot_w:7.2f}s  modeled {tot_an:6.2f}s  "
              f"throughput {n_tok / max(tot_an, 1e-9):8.1f} tok/s (modeled)")
        return tot_w, toks

    results = {}
    if args.mode in ("seq", "both"):
        results["seq"] = run(RaLMSeq(eng, retr, rcfg, enc), "RaLMSeq")
    if args.mode in ("spec", "both"):
        label = "RaLMSpec" + ("+" + args.variant.upper() if args.variant else "")
        if args.concurrency > 1:
            results["spec"] = run_fleet(f"Fleet x{args.concurrency}")
        else:
            results["spec"] = run(RaLMSpec(eng, retr, rcfg, enc), label)
    if len(results) == 2:
        same = all(a == b for a, b in zip(results["seq"][1], results["spec"][1]))
        print(f"outputs identical: {same}   "
              f"speed-up {results['seq'][0] / max(results['spec'][0], 1e-9):.2f}x")


if __name__ == "__main__":
    main()
