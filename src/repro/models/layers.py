"""Shared neural building blocks.

Everything is written as pure functions over param pytrees so that the whole stack
jits/shards cleanly under pjit. Attention over long sequences is *blockwise*
(online-softmax over KV chunks, flash-attention-style) so `S x S` score matrices are
never materialized — required for the prefill_32k / long_500k shapes.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------------------
# sharding helper: constraint only when a mesh is in scope (no-op in plain CPU tests)
# --------------------------------------------------------------------------------------
def shard(x: jax.Array, spec: P) -> jax.Array:
    """Sharding constraint that degrades gracefully: axes missing from the current
    mesh are dropped, and any spec entry whose mesh-axis product does not divide the
    array dimension is dropped (e.g. KV=8 heads on a 16-way 'model' axis ->
    replicated). Keeps one set of constraints valid across 1-device CPU tests, the
    16x16 pod mesh and the 2x16x16 multi-pod mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

        def _filter(entry, dim):
            if entry is None:
                return None
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in axes if a in sizes)
            if not kept:
                return None
            prod = 1
            for a in kept:
                prod *= sizes[a]
            if dim % prod != 0:
                return None
            return kept if len(kept) > 1 else kept[0]

        entries = list(spec) + [None] * (x.ndim - len(spec))
        spec = P(*[_filter(e, x.shape[i]) for i, e in enumerate(entries[: x.ndim])])
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def batch_axes(mesh_axis_names) -> tuple:
    """The mesh axes batch is sharded over ('pod','data' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


# --------------------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (when rope_theta == 0)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d_model // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------------------
# blockwise (online-softmax) attention — full-sequence (train / prefill)
# --------------------------------------------------------------------------------------
_NEG_INF = -1e30


def _mask_block(qi: jax.Array, kj: jax.Array, *, causal: bool, window: int,
                prefix_len: int, valid_len: Optional[jax.Array]) -> jax.Array:
    """(bq, bk) boolean allowed-mask for global query idx qi (bq,), key idx kj (bk,)."""
    allowed = jnp.ones((qi.shape[0], kj.shape[0]), dtype=bool)
    qi_ = qi[:, None]
    kj_ = kj[None, :]
    if causal:
        c = kj_ <= qi_
        if prefix_len > 0:
            c = c | ((qi_ < prefix_len) & (kj_ < prefix_len))
        allowed &= c
    if window > 0:
        allowed &= kj_ > qi_ - window
    if valid_len is not None:
        allowed &= kj_ < valid_len
    return allowed


def blockwise_attention(
    q: jax.Array,                # (B, S, H, hd)
    k: jax.Array,                # (B, T, KV, hd)
    v: jax.Array,                # (B, T, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention. O(bq*bk) live memory; causal chunks are *skipped*
    (dynamic inner fori_loop bound), not just masked, so FLOPs ~ S^2/2 not S^2."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    n_q = -(-S // q_chunk)
    n_kv = -(-T // kv_chunk)
    # pad S/T to chunk multiples
    pad_q = n_q * q_chunk - S
    pad_kv = n_kv * kv_chunk - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    qg = q.reshape(B, n_q, q_chunk, KV, G, hd)
    kg = k.reshape(B, n_kv, kv_chunk, KV, hd)
    vg = v.reshape(B, n_kv, kv_chunk, KV, hd)

    def q_body(qi: int):
        q_blk = qg[:, qi]                                    # (B, bq, KV, G, hd)
        q_idx = qi * q_chunk + jnp.arange(q_chunk)

        acc0 = jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, q_chunk, KV, G), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KV, G), jnp.float32)

        def kv_body(kj, carry):
            acc, m, l = carry
            k_blk = kg[:, kj]                                # (B, bk, KV, hd)
            v_blk = vg[:, kj]
            k_idx = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale  # (B,KV,G,bq,bk)
            msk = _mask_block(q_idx, k_idx, causal=causal, window=window,
                              prefix_len=prefix_len,
                              valid_len=jnp.asarray(T))
            s = jnp.where(msk[None, None, None], s, _NEG_INF)
            s = jnp.moveaxis(s, 3, 1)                        # (B,bq,KV,G,bk)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgt,btkh->bqkgh", p, v_blk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return acc_new, m_new, l_new

        # static per-chunk bounds (qi is a Python int — the q-chunk loop is
        # unrolled) => causal chunk SKIPPING (FLOPs ~ S^2/2, not masked S^2) while
        # staying reverse-differentiable for the training path.
        if causal and window > 0:
            lo = max(0, (qi * q_chunk - window) // kv_chunk)
            hi = min(n_kv, ((qi + 1) * q_chunk - 1) // kv_chunk + 1)
        elif causal:
            lo = 0
            hi = min(n_kv, ((qi + 1) * q_chunk - 1) // kv_chunk + 1)
        else:
            lo, hi = 0, n_kv
        acc, m, l = jax.lax.fori_loop(lo, hi, kv_body, (acc0, m0, l0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)                            # (B,bq,KV,G,hd)

    outs = jnp.stack([q_body(qi) for qi in range(n_q)])       # (n_q,B,bq,KV,G,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_q * q_chunk, H, hd)
    return out[:, :S]


def plain_attention(q, k, v, *, causal=True, window=0, prefix_len=0,
                    scale=None) -> jax.Array:
    """Reference / short-sequence attention (materializes S x T scores)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,btkh->bqkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    msk = _mask_block(jnp.arange(S), jnp.arange(T), causal=causal, window=window,
                      prefix_len=prefix_len, valid_len=None)
    s = jnp.where(msk[None, :, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgt,btkh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(
    q: jax.Array,                # (B, 1, H, hd) — current-step query (already roped)
    k_cache: jax.Array,          # (B, W, KV, hd) — roped keys (ring or linear buffer)
    v_cache: jax.Array,          # (B, W, KV, hd)
    cache_len: jax.Array,        # scalar/per-batch number of valid entries
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode against a KV cache. Ring-buffer validity is expressed purely
    through ``cache_len`` masking (entries >= cache_len are invalid); for ring buffers
    cache_len == W once wrapped. Softmax order-invariance makes ring rotation a no-op."""
    B, _, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    # accumulate in f32 via preferred_element_type — never materialize an f32 COPY
    # of the (huge) cache (that copy doubled decode HBM traffic; EXPERIMENTS §Perf)
    s = jnp.einsum("bkgh,btkh->bkgt", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale   # (B,KV,G,W)
    idx = jnp.arange(W)
    valid = idx[None, :] < jnp.reshape(cache_len, (-1, 1))       # (B,W) or (1,W)
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    sc_in = 1.0 / math.sqrt(d_model)
    sc_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * sc_out).astype(dtype),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, P(("pod", "data"), None, "model"))
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# --------------------------------------------------------------------------------------
# attention module (projections + rope + blockwise/decode core)
# --------------------------------------------------------------------------------------
def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(keys[0], (d, H * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(keys[1], (d, KV * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(keys[2], (d, KV * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(keys[3], (H * hd, d)) / math.sqrt(H * hd)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p, cfg, xq, xkv):
    B, S = xq.shape[0], xq.shape[1]
    T = xkv.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", xq, p["wq"])
    k = jnp.einsum("btd,dh->bth", xkv, p["wk"])
    v = jnp.einsum("btd,dh->bth", xkv, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def apply_self_attention(p, cfg, x, positions, *, causal=True, window=0,
                         prefix_len=0, q_chunk=1024, kv_chunk=1024) -> jax.Array:
    """Full-sequence self-attention (train / prefill path)."""
    q, k, v = _project_qkv(p, cfg, x, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, P(("pod", "data"), None, "model", None))
    k = shard(k, P(("pod", "data"), None, "model", None))
    S = x.shape[1]
    if S <= max(q_chunk, 2048):
        out = plain_attention(q, k, v, causal=causal, window=window,
                              prefix_len=prefix_len)
    else:
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  prefix_len=prefix_len, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk)
    out = out.reshape(x.shape[0], S, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def _mesh_active() -> bool:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        return mesh is not None and not mesh.empty and len(mesh.axis_names) > 0 \
            and any(int(s) > 1 for s in mesh.axis_sizes)
    except Exception:
        return False


def apply_self_attention_decode(p, cfg, x, position, k_cache, v_cache, cache_len,
                                write_idx) -> tuple:
    """One-token decode: project, rope at `position`, write ring slot, attend.

    Ring write: under a >1-device mesh the cache window may be sharded over
    'model'; a dynamic_update_slice at a dynamic index into a sharded dim makes
    GSPMD all-gather the whole cache per layer (measured: 56GB/step on
    kimi x decode_32k — EXPERIMENTS §Perf). The masked elementwise write shards
    cleanly; single-device serving keeps the cheap in-place slice update.

    Returns (out, new_k_cache, new_v_cache).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, x)                   # S == 1
    pos = jnp.reshape(position, (-1, 1)) * jnp.ones((B, 1), jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    if _mesh_active() or jnp.ndim(write_idx) > 0:
        # vector write_idx (B,): per-slot ring positions (multi-request serving —
        # each fleet slot sits at its own absolute position)
        slot = (jnp.arange(k_cache.shape[1])[None, :, None, None]
                == jnp.reshape(write_idx, (-1, 1, 1, 1)))
        k_cache = jnp.where(slot, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(slot, v.astype(v_cache.dtype), v_cache)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), write_idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), write_idx, axis=1)
    out = decode_attention(q, k_cache, v_cache, cache_len)
    out = out.reshape(B, 1, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_cache, v_cache


def apply_cross_attention(p, cfg, x, mem_k, mem_v) -> jax.Array:
    """Decoder cross-attention over precomputed encoder memory K/V."""
    B, S = x.shape[0], x.shape[1]
    H, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    out = plain_attention(q, mem_k, mem_v, causal=False)
    out = out.reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def project_memory_kv(p, cfg, mem) -> tuple:
    """Project encoder output into the decoder cross-attention K/V once."""
    B, T = mem.shape[0], mem.shape[1]
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("btd,dh->bth", mem, p["wk"])
    v = jnp.einsum("btd,dh->bth", mem, p["wv"])
    if cfg.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k.reshape(B, T, KV, hd), v.reshape(B, T, KV, hd)
