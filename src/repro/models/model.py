"""Unified model assembly for all assigned architectures.

Layer-stacking discipline (DESIGN §7): per-layer *signatures* (mixer kind, MoE?) are
computed from the config; a maximal periodic suffix is `lax.scan`'d over stacked
params (so an 80-layer dense model lowers as one rolled loop; Jamba scans over its
8-layer period) while any irregular prefix (e.g. kimi-k2's dense first layer) runs as
single blocks.

Step kinds:
  * ``forward``              — full sequence (train / prefill), scan-rolled.
  * ``prefill``              — unrolled walk collecting KV caches + recurrent states.
  * ``decode_step``          — one token, per-layer state list (serving path).
  * ``decode_step_stacked``  — one token, scan-rolled stacked state (dry-run path,
                               keeps the HLO compact for 61–80 layer models).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import shard

Sig = Tuple[str, bool]   # (mixer kind, has_moe)


# --------------------------------------------------------------------------------------
# layer plan
# --------------------------------------------------------------------------------------
def signatures(cfg: ModelConfig) -> list:
    kinds = cfg.layer_kinds()
    return [(kinds[i], cfg.layer_has_moe(i)) for i in range(cfg.num_layers)]


def layer_plan(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prefix_singles, period, n_repeats); n_prefix + period*n_repeats == L."""
    sigs = signatures(cfg)
    LY = len(sigs)
    for p in range(1, min(8, LY) + 1):
        for k in range(0, min(4, LY)):
            tail = sigs[k:]
            if tail and len(tail) % p == 0 and all(
                    tail[i] == tail[i % p] for i in range(len(tail))):
                return k, p, len(tail) // p
    return LY, 1, 0


# --------------------------------------------------------------------------------------
# block init / apply (full sequence)
# --------------------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, sig: Sig, dtype, cross: bool) -> dict:
    kind, has_moe = sig
    keys = jax.random.split(key, 4)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if kind == "attn":
        p["mixer"] = L.init_attention(keys[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = SSM.init_mamba(keys[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = SSM.init_mlstm(keys[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = SSM.init_slstm(keys[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_cross"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = L.init_attention(keys[1], cfg, dtype, cross=True)
    if has_moe:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = MOE.init_moe(keys[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = L.init_mlp(keys[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def _apply_block(bp, cfg, sig: Sig, x, positions, *, mem=None, window=0,
                 prefix_len=0, cross: bool = False, moe_exact: bool = False):
    kind, has_moe = sig
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if kind == "attn":
        h = L.apply_self_attention(bp["mixer"], cfg, h, positions, causal=True,
                                   window=window, prefix_len=prefix_len)
    elif kind == "mamba":
        h = SSM.apply_mamba(bp["mixer"], cfg, h)
    elif kind == "mlstm":
        h = SSM.apply_mlstm(bp["mixer"], cfg, h)
    elif kind == "slstm":
        h = SSM.apply_slstm(bp["mixer"], cfg, h)
    x = x + h
    if cross and mem is not None:
        h = L.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
        mem_k, mem_v = L.project_memory_kv(bp["cross"], cfg, mem)
        h = L.apply_cross_attention(bp["cross"], cfg, h, mem_k, mem_v)
        x = x + h
    if has_moe:
        h = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
        moe_fn = MOE.apply_moe_exact if moe_exact else MOE.apply_moe
        h, a = moe_fn(bp["moe"], cfg, h)
        aux = aux + a
        x = x + h
    elif "ffn" in bp:
        h = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
        x = x + L.apply_mlp(bp["ffn"], h)
    x = shard(x, P(("pod", "data"), None, None))
    return x, aux


def _collect_kv(bp, cfg, x, positions):
    """Roped K/V of the full sequence for decode handoff (attention layers)."""
    h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
    _, k, v = L._project_qkv(bp["mixer"], cfg, h, h)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return k, v


# --------------------------------------------------------------------------------------
# block apply (decode step)
# --------------------------------------------------------------------------------------
def _apply_block_decode(bp, cfg, sig: Sig, x, state, pos, write_idx, cache_len,
                        *, cross: bool = False, exact_moe: bool = True):
    kind, has_moe = sig
    h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if kind == "attn":
        h, k_new, v_new = L.apply_self_attention_decode(
            bp["mixer"], cfg, h, pos, state["k"], state["v"], cache_len, write_idx)
        state = dict(state, k=k_new, v=v_new)
    elif kind == "mamba":
        h, st = SSM.apply_mamba_step(bp["mixer"], cfg, h, state["ssm"])
        state = dict(state, ssm=st)
    elif kind == "mlstm":
        h, st = SSM.apply_mlstm_step(bp["mixer"], cfg, h, state["ssm"])
        state = dict(state, ssm=st)
    elif kind == "slstm":
        h, st = SSM.apply_slstm_step(bp["mixer"], cfg, h, state["ssm"])
        state = dict(state, ssm=st)
    x = x + h
    if cross and "cross_k" in state:
        h = L.rms_norm(x, bp["norm_cross"], cfg.norm_eps)
        h = L.apply_cross_attention(bp["cross"], cfg, h,
                                    state["cross_k"], state["cross_v"])
        x = x + h
    if has_moe:
        h = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
        # serving decode uses the capacity path only on dry-run-scale meshes; the
        # single-token batch fits capacity exactly there. The exact (dropless) MoE
        # keeps decode consistent with prefill at serving scale.
        moe_fn = MOE.apply_moe_exact if exact_moe else MOE.apply_moe
        h, _ = moe_fn(bp["moe"], cfg, h)
        x = x + h
    elif "ffn" in bp:
        h = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
        x = x + L.apply_mlp(bp["ffn"], h)
    return x, state


def _init_layer_state(cfg, sig: Sig, batch: int, window: int, dtype,
                      cross_frames: int = 0) -> dict:
    kind, _ = sig
    st: dict = {}
    if kind == "attn":
        st["k"] = jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), dtype)
        st["v"] = jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), dtype)
    elif kind == "mamba":
        st["ssm"] = SSM.init_mamba_state(cfg, batch, dtype)
    elif kind == "mlstm":
        st["ssm"] = SSM.init_mlstm_state(cfg, batch, dtype)
    elif kind == "slstm":
        st["ssm"] = SSM.init_slstm_state(cfg, batch, dtype)
    if cross_frames:
        st["cross_k"] = jnp.zeros((batch, cross_frames, cfg.num_kv_heads, cfg.head_dim), dtype)
        st["cross_v"] = jnp.zeros((batch, cross_frames, cfg.num_kv_heads, cfg.head_dim), dtype)
    return st


def _sinusoid_at(pos, d_model: int):
    """Sinusoidal embedding at `pos` (scalar or per-slot (B,)) -> (B|1, 1, d)."""
    posf = jnp.atleast_1d(jnp.asarray(pos, jnp.float32)).reshape(-1, 1)
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * dim / max(d_model // 2 - 1, 1))
    ang = posf * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, None]


# --------------------------------------------------------------------------------------
# the Model facade
# --------------------------------------------------------------------------------------
@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- init -------------------------------------------------------------------------
    def init(self, key, dtype=jnp.float32) -> dict:
        cfg = self.cfg
        n_pre, period, n_rep = layer_plan(cfg)
        sigs = signatures(cfg)
        cross = cfg.family == "audio"
        keys = jax.random.split(key, cfg.num_layers + 4)
        params: dict = {
            "embed": (jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model))
                      * (1.0 / math.sqrt(cfg.d_model))).astype(dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_size))
                                 * (1.0 / math.sqrt(cfg.d_model))).astype(dtype)
        params["prefix"] = tuple(
            _init_block(keys[i], cfg, sigs[i], dtype, cross) for i in range(n_pre))
        stages = []
        for j in range(period):
            reps = [
                _init_block(keys[n_pre + r * period + j], cfg, sigs[n_pre + j],
                            dtype, cross)
                for r in range(n_rep)
            ]
            if not reps:
                continue
            stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
                          if n_rep > 1 else reps[0])
        params["blocks"] = tuple(stages)
        if cfg.family == "audio":
            ekeys = jax.random.split(keys[-3], cfg.encoder_layers)
            params["encoder"] = {
                "layers": tuple(
                    _init_block(ekeys[i], cfg, ("attn", False), dtype, cross=False)
                    for i in range(cfg.encoder_layers)),
                "final_norm": jnp.ones((cfg.d_model,), dtype),
            }
        return params

    # ---- encoder (audio) ----------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, F, d) precomputed conv-frontend embeddings (assignment stub)."""
        cfg = self.cfg
        x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)
        positions = jnp.arange(frames.shape[1])[None]
        for bp in params["encoder"]["layers"]:
            h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
            h = L.apply_self_attention(bp["mixer"], cfg, h, positions, causal=False)
            x = x + h
            h = L.rms_norm(x, bp["norm2"], cfg.norm_eps)
            x = x + L.apply_mlp(bp["ffn"], h)
        return L.rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # ---- embedding / prefix handling ----------------------------------------------------
    def _embed_inputs(self, params, tokens, extra):
        cfg = self.cfg
        x = params["embed"][tokens]
        prefix_len = 0
        if cfg.family == "vlm" and extra is not None and "patches" in extra:
            x = jnp.concatenate([extra["patches"].astype(x.dtype), x], axis=1)
            prefix_len = extra["patches"].shape[1]
        if cfg.rope_theta <= 0:
            x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        return x, prefix_len

    # ---- full-sequence forward -----------------------------------------------------------
    def forward(self, params, tokens: jax.Array, *, extra: Optional[dict] = None,
                window: int = 0, last_only: bool = False, remat: bool = False):
        """tokens: (B, S_text). Returns (logits, aux_loss). ``last_only`` unembeds
        only the final position (inference-prefill: logits for the next token).
        ``remat`` checkpoints each BLOCK inside the layer scan — without it the
        scan's reverse pass stores every layer's MoE/attention intermediates
        (measured 832GB/chip on kimi x train_4k; EXPERIMENTS §Perf)."""
        cfg = self.cfg
        n_pre, period, n_rep = layer_plan(cfg)
        sigs = signatures(cfg)
        cross = cfg.family == "audio"
        x, prefix_len = self._embed_inputs(params, tokens, extra)
        mem = self.encode(params, extra["frames"]) if cross else None
        positions = jnp.arange(x.shape[1])[None]
        x = shard(x, P(("pod", "data"), None, None))
        aux = jnp.zeros((), jnp.float32)

        def block_fn(bp, sig, x, positions):
            return _apply_block(bp, cfg, sig, x, positions, mem=mem,
                                window=window, prefix_len=prefix_len, cross=cross)

        if remat:
            block_fn = jax.checkpoint(block_fn, static_argnums=(1,))

        for i, bp in enumerate(params["prefix"]):
            x, a = block_fn(bp, sigs[i], x, positions)
            aux += a

        if n_rep == 1:
            for j, bp in enumerate(params["blocks"]):
                x, a = block_fn(bp, sigs[n_pre + j], x, positions)
                aux += a
        elif n_rep > 1:
            period_sigs = [sigs[n_pre + j] for j in range(period)]

            def body(carry, stage_params):
                xx, acc = carry
                for j in range(period):
                    xx, a = block_fn(stage_params[j], period_sigs[j], xx, positions)
                    acc = acc + a
                return (xx, acc), None

            (x, aux), _ = jax.lax.scan(body, (x, aux), tuple(params["blocks"]))

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if last_only:
            x = x[:, -1:]
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x, unembed)
        logits = shard(logits, P(("pod", "data"), None, "model"))
        return logits, aux

    # ---- per-layer param view ------------------------------------------------------------
    def _layer_params(self, params, layer_idx: int):
        n_pre, period, n_rep = layer_plan(self.cfg)
        if layer_idx < n_pre:
            return params["prefix"][layer_idx]
        off = layer_idx - n_pre
        r, j = divmod(off, period)
        stacked = params["blocks"][j]
        if n_rep <= 1:
            return stacked
        return jax.tree.map(lambda t: t[r], stacked)

    # ---- decode state ---------------------------------------------------------------------
    def init_decode_state(self, batch: int, window: int, dtype=jnp.float32) -> list:
        cfg = self.cfg
        cross_frames = cfg.encoder_frames if cfg.family == "audio" else 0
        return [_init_layer_state(cfg, s, batch, window, dtype, cross_frames)
                for s in signatures(cfg)]

    def init_decode_state_stacked(self, batch: int, window: int, dtype=jnp.float32):
        """Stacked layout mirroring the param layout (dry-run / compiled decode)."""
        cfg = self.cfg
        n_pre, period, n_rep = layer_plan(cfg)
        sigs = signatures(cfg)
        cross_frames = cfg.encoder_frames if cfg.family == "audio" else 0
        prefix = tuple(_init_layer_state(cfg, sigs[i], batch, window, dtype,
                                         cross_frames) for i in range(n_pre))
        stages = []
        for j in range(period):
            if n_rep == 0:
                break
            one = _init_layer_state(cfg, sigs[n_pre + j], batch, window, dtype,
                                    cross_frames)
            if n_rep > 1:
                one = jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (n_rep,) + t.shape), one)
            stages.append(one)
        return {"prefix": prefix, "stages": tuple(stages)}

    # ---- decode (serving path: per-layer list) ----------------------------------------------
    def decode_step(self, params, state: list, token: jax.Array, pos: jax.Array):
        """token: (B,) int32; pos: scalar absolute position shared by the batch, or
        per-slot (B,) positions (fleet serving: every slot decodes at its own
        absolute position). -> (logits (B,V), state)."""
        cfg = self.cfg
        sigs = signatures(cfg)
        x = params["embed"][token][:, None]
        if cfg.rope_theta <= 0:
            x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)
        cross = cfg.family == "audio"
        new_state = []
        for i, sig in enumerate(sigs):
            bp = self._layer_params(params, i)
            st = state[i]
            write_idx, cache_len = self._ring(st, sig, pos)
            x, st = _apply_block_decode(bp, cfg, sig, x, st, pos, write_idx,
                                        cache_len, cross=cross)
            new_state.append(st)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x, unembed)[:, 0]
        return logits, new_state

    @staticmethod
    def _ring(st, sig, pos):
        if sig[0] == "attn":
            W = st["k"].shape[1]
            return (pos % W).astype(jnp.int32), jnp.minimum(pos + 1, W).astype(jnp.int32)
        return jnp.int32(0), jnp.int32(0)

    # ---- decode (dry-run path: stacked state, scan-rolled) -----------------------------------
    def decode_step_stacked(self, params, state: dict, token: jax.Array,
                            pos: jax.Array):
        cfg = self.cfg
        n_pre, period, n_rep = layer_plan(cfg)
        sigs = signatures(cfg)
        cross = cfg.family == "audio"
        x = params["embed"][token][:, None]
        if cfg.rope_theta <= 0:
            x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)

        new_prefix = []
        for i, st in enumerate(state["prefix"]):
            bp = params["prefix"][i]
            write_idx, cache_len = self._ring(st, sigs[i], pos)
            x, st = _apply_block_decode(bp, cfg, sigs[i], x, st, pos, write_idx,
                                        cache_len, cross=cross, exact_moe=False)
            new_prefix.append(st)

        new_stages = state["stages"]
        if n_rep == 1:
            new_stages = []
            for j, bp in enumerate(params["blocks"]):
                sig = sigs[n_pre + j]
                st = state["stages"][j]
                write_idx, cache_len = self._ring(st, sig, pos)
                x, st = _apply_block_decode(bp, cfg, sig, x, st, pos, write_idx,
                                            cache_len, cross=cross,
                                            exact_moe=False)
                new_stages.append(st)
            new_stages = tuple(new_stages)
        elif n_rep > 1:
            period_sigs = [sigs[n_pre + j] for j in range(period)]

            def body(xx, inp):
                stage_params, stage_states = inp
                outs = []
                for j in range(period):
                    st = stage_states[j]
                    write_idx, cache_len = self._ring(st, period_sigs[j], pos)
                    xx, st = _apply_block_decode(stage_params[j], cfg,
                                                 period_sigs[j], xx, st, pos,
                                                 write_idx, cache_len, cross=cross,
                                                 exact_moe=False)
                    outs.append(st)
                return xx, tuple(outs)

            x, new_stages = jax.lax.scan(
                body, x, (tuple(params["blocks"]), state["stages"]))

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x, unembed)[:, 0]
        return logits, {"prefix": tuple(new_prefix), "stages": new_stages}

    # ---- prefill ---------------------------------------------------------------------------
    def prefill(self, params, tokens, *, extra=None, window_cache: int = 0,
                dtype=jnp.float32):
        """Unrolled full-sequence walk that also builds the decode state.

        Returns (last_logits (B, V), state list, next_pos scalar).
        """
        cfg = self.cfg
        sigs = signatures(cfg)
        cross = cfg.family == "audio"
        B = tokens.shape[0]
        x, prefix_len = self._embed_inputs(params, tokens, extra)
        S = x.shape[1]
        # default: full-attention decode with headroom (W=S would evict position 0
        # on the very first decode step — sliding-window semantics, not intended)
        W = window_cache or (S + 512)
        positions = jnp.arange(S)[None]
        mem = self.encode(params, extra["frames"]) if cross else None
        state = self.init_decode_state(B, W, dtype)

        for i, sig in enumerate(sigs):
            bp = self._layer_params(params, i)
            if sig[0] == "attn":
                k, v = _collect_kv(bp, cfg, x, positions)
                take = min(W, S)
                kk = k[:, -take:].astype(state[i]["k"].dtype)
                vv = v[:, -take:].astype(state[i]["v"].dtype)
                if take < W:
                    kk = jnp.pad(kk, ((0, 0), (0, W - take), (0, 0), (0, 0)))
                    vv = jnp.pad(vv, ((0, 0), (0, W - take), (0, 0), (0, 0)))
                if S > W:
                    # ring alignment: position p lives at index p % W
                    kk = jnp.roll(kk, S % W, axis=1)
                    vv = jnp.roll(vv, S % W, axis=1)
                state[i]["k"], state[i]["v"] = kk, vv
            else:
                h = L.rms_norm(x, bp["norm1"], cfg.norm_eps)
                state[i]["ssm"] = _final_state(bp["mixer"], cfg, sig[0], h)
            if cross:
                mk, mv = L.project_memory_kv(bp["cross"], cfg, mem)
                state[i]["cross_k"] = mk.astype(state[i]["cross_k"].dtype)
                state[i]["cross_v"] = mv.astype(state[i]["cross_v"].dtype)
            x, _ = _apply_block(bp, cfg, sig, x, positions, mem=mem,
                                prefix_len=prefix_len, cross=cross, moe_exact=True)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x, unembed)
        return logits[:, -1], state, jnp.int32(S)


def _final_state(mp, cfg, kind: str, h: jax.Array) -> dict:
    """Final recurrent state after consuming h (B, S, d) — stepwise scan."""
    B = h.shape[0]
    if kind == "mamba":
        st = SSM.init_mamba_state(cfg, B, h.dtype)
        step = lambda s, xt: (SSM.apply_mamba_step(mp, cfg, xt[:, None], s)[1], None)
    elif kind == "mlstm":
        st = SSM.init_mlstm_state(cfg, B, h.dtype)
        step = lambda s, xt: (SSM.apply_mlstm_step(mp, cfg, xt[:, None], s)[1], None)
    else:
        st = SSM.init_slstm_state(cfg, B, h.dtype)
        step = lambda s, xt: (SSM.apply_slstm_step(mp, cfg, xt[:, None], s)[1], None)
    st, _ = jax.lax.scan(step, st, h.swapaxes(0, 1))
    return st


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
