"""State-space and recurrent sequence mixers: Mamba (Jamba's SSM), mLSTM and sLSTM
(xLSTM).

TPU adaptation (DESIGN §3): the CUDA selective-scan kernel becomes a **chunked
associative scan** — `lax.scan` over chunks of the sequence carrying the recurrent
state, `lax.associative_scan` within a chunk. The chunk bound keeps the materialized
(B, chunk, d_inner, d_state) tensor inside a VMEM-sized budget instead of the
O(B*S*d_inner*d_state) blow-up of a naive parallel scan.

mLSTM uses the chunkwise linear-attention formulation (intra-chunk quadratic,
inter-chunk recurrent); the stepwise recurrence doubles as the decode step and the
test oracle. sLSTM is inherently sequential (per the xLSTM paper) and is a plain
`lax.scan` over time.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-6


# ======================================================================================
# Mamba
# ======================================================================================
def mamba_dims(cfg) -> Tuple[int, int, int]:
    d_in = cfg.ssm.expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_in, cfg.ssm.d_state, dt_rank


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, N, dt_rank = mamba_dims(cfg)
    dc = cfg.ssm.d_conv
    keys = jax.random.split(key, 7)
    sc = 1.0 / math.sqrt(d)
    return {
        "in_proj": (jax.random.normal(keys[0], (d, 2 * d_in)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (dc, d_in)) / math.sqrt(dc)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": (jax.random.normal(keys[2], (d_in, dt_rank + 2 * N))
                   / math.sqrt(d_in)).astype(dtype),
        "dt_proj": (jax.random.normal(keys[3], (dt_rank, d_in))
                    / math.sqrt(dt_rank)).astype(dtype),
        "dt_bias": jnp.full((d_in,), -2.0, jnp.float32),   # softplus(-2) ~ 0.13
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": (jax.random.normal(keys[4], (d_in, d)) / math.sqrt(d_in)).astype(dtype),
    }


def _mamba_bcdt(p, cfg, u):
    """u: (..., d_in) conv+silu'd input -> (B_mat, C_mat, dt) per position."""
    _, N, dt_rank = mamba_dims(cfg)
    proj = jnp.einsum("...i,ij->...j", u, p["x_proj"]).astype(jnp.float32)
    dt_r, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,ri->...i", dt_r, p["dt_proj"].astype(jnp.float32))
                         + p["dt_bias"])                    # (..., d_in)
    return Bm, Cm, dt


def _causal_conv(p, x_in, conv_state=None):
    """Depthwise causal conv. x_in: (B, S, d_in). conv_state: (B, dc-1, d_in)."""
    dc = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x_in.shape[0], dc - 1, x_in.shape[2]), x_in.dtype)
    else:
        pad = conv_state.astype(x_in.dtype)
    xp = jnp.concatenate([pad, x_in], axis=1)               # (B, S+dc-1, d_in)
    out = sum(xp[:, i:i + x_in.shape[1]] * p["conv_w"][i] for i in range(dc))
    new_state = xp[:, -(dc - 1):] if dc > 1 else pad
    return out + p["conv_b"], new_state


def apply_mamba(p, cfg, x: jax.Array) -> jax.Array:
    """Full-sequence selective scan. x: (B, S, d)."""
    B, S, d = x.shape
    d_in, N, _ = mamba_dims(cfg)
    chunk = min(cfg.ssm.chunk, S)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, _ = _causal_conv(p, x_in)
    u = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    Bm, Cm, dt = _mamba_bcdt(p, cfg, u)                     # (B,S,N),(B,S,N),(B,S,d_in)
    A = -jnp.exp(p["A_log"])                                # (d_in, N)

    # decay a_t = exp(dt_t * A)  (B,S,d_in,N);  drive b_t = dt_t * B_t * u_t
    uf = u.astype(jnp.float32)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        uf = jnp.pad(uf, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def chunk_body(h, inputs):
        uc, Bc, Cc, dtc = inputs                            # (B, L, ...)
        a = jnp.exp(dtc[..., None] * A)                     # (B,L,d_in,N)
        b = (dtc * uc)[..., None] * Bc[:, :, None, :]       # (B,L,d_in,N)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_sc * h[:, None] + b_sc                       # (B,L,d_in,N)
        y = jnp.einsum("blin,bln->bli", hs, Cc)             # (B,L,d_in)
        return hs[:, -1], y

    u_ch = uf.reshape(B, n_chunks, chunk, d_in).swapaxes(0, 1)
    B_ch = Bm.reshape(B, n_chunks, chunk, N).swapaxes(0, 1)
    C_ch = Cm.reshape(B, n_chunks, chunk, N).swapaxes(0, 1)
    dt_ch = dt.reshape(B, n_chunks, chunk, d_in).swapaxes(0, 1)
    h0 = jnp.zeros((B, d_in, N), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, (u_ch, B_ch, C_ch, dt_ch))
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * chunk, d_in)[:, :S]
    y = y + uf[:, :S] * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def init_mamba_state(cfg, batch: int, dtype) -> dict:
    d_in, N, _ = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, d_in, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, d_in), dtype),
    }


def apply_mamba_step(p, cfg, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """One decode step. x: (B, 1, d)."""
    B = x.shape[0]
    d_in, N, _ = mamba_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, new_conv = _causal_conv(p, x_in, state["conv"])
    u = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)
    Bm, Cm, dt = _mamba_bcdt(p, cfg, u)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                       # (B,d_in,N)
    b = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0])[:, None]       # (B,1,d_in)
    y = y + u.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h, "conv": new_conv}


# ======================================================================================
# mLSTM (xLSTM matrix-memory block)
# ======================================================================================
def mlstm_dims(cfg) -> Tuple[int, int]:
    d_in = 2 * cfg.d_model        # proj_factor 2 per xLSTM mLSTM block
    hd = d_in // cfg.num_heads
    return d_in, hd


def init_mlstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, hd = mlstm_dims(cfg)
    H = cfg.num_heads
    keys = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    sci = 1.0 / math.sqrt(d_in)
    return {
        "up_proj": (jax.random.normal(keys[0], (d, 2 * d_in)) * sc).astype(dtype),
        "wq": (jax.random.normal(keys[1], (d_in, d_in)) * sci).astype(dtype),
        "wk": (jax.random.normal(keys[2], (d_in, d_in)) * sci).astype(dtype),
        "wv": (jax.random.normal(keys[3], (d_in, d_in)) * sci).astype(dtype),
        "w_if": (jax.random.normal(keys[4], (d_in, 2 * H)) * sci).astype(jnp.float32),
        "b_i": jnp.full((H,), -3.0, jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "down_proj": (jax.random.normal(keys[5], (d_in, d)) * sci).astype(dtype),
    }


def _mlstm_qkvif(p, cfg, xu):
    """xu: (B, S, d_in) -> per-head q,k,v (B,S,H,hd), log_f, log_i (B,S,H)."""
    B, S, d_in = xu.shape
    H = cfg.num_heads
    hd = d_in // H
    q = jnp.einsum("bsi,ij->bsj", xu, p["wq"]).reshape(B, S, H, hd)
    k = (jnp.einsum("bsi,ij->bsj", xu, p["wk"]) / math.sqrt(hd)).reshape(B, S, H, hd)
    v = jnp.einsum("bsi,ij->bsj", xu, p["wv"]).reshape(B, S, H, hd)
    gates = jnp.einsum("bsi,ih->bsh", xu.astype(jnp.float32), p["w_if"])
    gi, gf = jnp.split(gates, 2, axis=-1)
    log_i = jnp.clip(gi + p["b_i"], -12.0, 4.0)             # capped exp input gate
    log_f = jax.nn.log_sigmoid(gf + p["b_f"])               # f in (0,1)
    return q, k, v, log_f, log_i


def apply_mlstm(p, cfg, x: jax.Array) -> jax.Array:
    """Chunkwise-parallel mLSTM. x: (B, S, d)."""
    B, S, d = x.shape
    d_in, hd = mlstm_dims(cfg)
    H = cfg.num_heads
    chunk = min(cfg.ssm.chunk if cfg.ssm else 256, S)
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, log_f, log_i = _mlstm_qkvif(p, cfg, xu)

    n_ch = -(-S // chunk)
    pad = n_ch * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))   # log f=0 -> f=1 ok
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)

    def resh(t):
        return t.reshape((B, n_ch, chunk) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lfc, lic = map(resh, (q, k, v, log_f, log_i))

    def chunk_body(carry, inp):
        C0, n0 = carry                                       # (B,H,hd,hd), (B,H,hd)
        qb, kb, vb, lf, li = inp                             # (B,L,H,*)
        cf = jnp.cumsum(lf, axis=1)                          # (B,L,H) cumulative log f
        # intra-chunk: w_ij = exp(cf_i - cf_j + li_j) for j <= i  (<= exp(li) stable)
        qk = jnp.einsum("bihd,bjhd->bhij", qb.astype(jnp.float32),
                        kb.astype(jnp.float32))              # (B,H,L,L)
        logw = (cf[:, :, None] - cf[:, None, :] + li[:, None, :])  # (B,L,L,H)
        logw = jnp.moveaxis(logw, 3, 1)                      # (B,H,L,L)
        L = qb.shape[1]
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal, jnp.exp(logw), 0.0)
        a = qk * w                                           # weighted scores
        inter_scale = jnp.exp(cf)                            # (B,L,H)
        y_intra = jnp.einsum("bhij,bjhd->bihd", a, vb.astype(jnp.float32))
        y_inter = jnp.einsum("bihd,bhde->bihe", qb.astype(jnp.float32), C0) \
            * inter_scale[..., None]
        den_intra = jnp.sum(a, axis=-1)                      # (B,H,L)
        den_inter = jnp.einsum("bihd,bhd->bhi", qb.astype(jnp.float32), n0) \
            * jnp.moveaxis(inter_scale, 1, 2)
        den = jnp.abs(den_intra + den_inter)                 # (B,H,L)
        y = (y_intra + y_inter) / jnp.maximum(jnp.moveaxis(den, 1, 2)[..., None], 1.0)
        # end-of-chunk state
        decay_to_end = jnp.exp(cf[:, -1:, :] - cf + li)      # (B,L,H)
        C1 = jnp.exp(cf[:, -1])[..., None, None] * C0 + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", decay_to_end, kb.astype(jnp.float32),
            vb.astype(jnp.float32))
        n1 = jnp.exp(cf[:, -1])[..., None] * n0 + jnp.einsum(
            "bjh,bjhd->bhd", decay_to_end, kb.astype(jnp.float32))
        return (C1, n1), y

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, (C0, n0), (qc, kc, vc, lfc, lic))
    y = ys.swapaxes(0, 1).reshape(B, n_ch * chunk, H, hd)[:, :S]
    y = y.reshape(B, S, d_in)
    from repro.models.layers import rms_norm
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["down_proj"])


def init_mlstm_state(cfg, batch: int, dtype) -> dict:
    d_in, hd = mlstm_dims(cfg)
    H = cfg.num_heads
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
    }


def apply_mlstm_step(p, cfg, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """One decode step (the stepwise recurrence; also the chunkwise oracle)."""
    B = x.shape[0]
    d_in, hd = mlstm_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, log_f, log_i = _mlstm_qkvif(p, cfg, xu)         # (B,1,H,hd)
    f = jnp.exp(log_f[:, 0])[..., None, None]                # (B,H,1,1)
    i = jnp.exp(log_i[:, 0])[..., None, None]
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    C = f * state["C"] + i * jnp.einsum("bhd,bhe->bhde",
                                        jnp.moveaxis(kf, 1, 1), vf)
    n = f[..., 0] * state["n"] + i[..., 0] * kf
    qf = q[:, 0].astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n))
    y = num / jnp.maximum(den, 1.0)[..., None]               # (B,H,hd)
    y = y.reshape(B, 1, d_in)
    from repro.models.layers import rms_norm
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["down_proj"])
    return out, {"C": C, "n": n}


# ======================================================================================
# sLSTM (xLSTM scalar-memory block) — inherently sequential
# ======================================================================================
def init_slstm(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.num_heads
    hd = d_in // H
    keys = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    return {
        "up_proj": (jax.random.normal(keys[0], (d, 2 * d_in)) * sc).astype(dtype),
        "w_gates": (jax.random.normal(keys[1], (d_in, 4 * d_in))
                    / math.sqrt(d_in)).astype(jnp.float32),
        # block-diagonal recurrent weights: per head (hd, 4*hd)
        "r_gates": (jax.random.normal(keys[2], (H, hd, 4 * hd))
                    / math.sqrt(hd)).astype(jnp.float32),
        "b_gates": jnp.concatenate([
            jnp.full((d_in,), -3.0), jnp.full((d_in,), 3.0),
            jnp.zeros((d_in,)), jnp.zeros((d_in,))]).astype(jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "down_proj": (jax.random.normal(keys[3], (d_in, d))
                      / math.sqrt(d_in)).astype(dtype),
    }


def init_slstm_state(cfg, batch: int, dtype) -> dict:
    d_in = 2 * cfg.d_model
    z = jnp.zeros((batch, d_in), jnp.float32)
    return {"c": z, "n": z + _EPS, "h": z, "m": z - 10.0}


def _slstm_cell(p, cfg, xw, st):
    """xw: (B, 4*d_in) precomputed input contribution; st: state dict."""
    H = cfg.num_heads
    B, d4 = xw.shape
    d_in = d4 // 4
    hd = d_in // H
    hview = st["h"].reshape(B, H, hd)
    rec = jnp.einsum("bhk,hkj->bhj", hview, p["r_gates"]).reshape(B, 4 * d_in)
    gates = xw + rec + p["b_gates"]
    gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
    # stabilized exponential gating (xLSTM eq. 15-17)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + st["m"], jnp.clip(gi, -12.0, 8.0))
    i = jnp.exp(jnp.clip(gi, -12.0, 8.0) - m_new)
    f = jnp.exp(log_f + st["m"] - m_new)
    c = f * st["c"] + i * jnp.tanh(gz)
    n = f * st["n"] + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, _EPS)
    return {"c": c, "n": n, "h": h, "m": m_new}


def apply_slstm(p, cfg, x: jax.Array) -> jax.Array:
    """Full-sequence sLSTM via lax.scan over time. x: (B, S, d)."""
    B, S, d = x.shape
    d_in = 2 * d
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    xw = jnp.einsum("bsi,ij->bsj", xu.astype(jnp.float32), p["w_gates"])

    def step(st, xw_t):
        st = _slstm_cell(p, cfg, xw_t, st)
        return st, st["h"]

    st0 = init_slstm_state(cfg, B, x.dtype)
    _, hs = jax.lax.scan(step, st0, xw.swapaxes(0, 1))       # (S,B,d_in)
    y = hs.swapaxes(0, 1)
    from repro.models.layers import rms_norm
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["down_proj"])


def apply_slstm_step(p, cfg, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xu, z = jnp.split(xz, 2, axis=-1)
    xw = jnp.einsum("bsi,ij->bsj", xu.astype(jnp.float32), p["w_gates"])[:, 0]
    st = _slstm_cell(p, cfg, xw, state)
    y = st["h"][:, None]
    from repro.models.layers import rms_norm
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["down_proj"]), st
