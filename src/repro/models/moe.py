"""Mixture-of-Experts FFN.

Dispatch is capacity-based **gather/scatter** (O(E*C*d) buffers), never the classic
one-hot einsum (O(T^2 * k) — quadratic in tokens, catastrophic at 65k tokens/device).
Token chunking (``cfg.moe.dispatch_chunk``) bounds the dispatch buffer; chunks are
processed under ``lax.scan`` so the HLO stays compact.

Parallelism: experts are sharded over 'model' (expert parallelism) with the expert
FFN dim over 'data', so every contraction is local up to one small (E, C, d) psum —
see EXPERIMENTS §Perf (kimi train iteration 2). Collectives are inserted by XLA
SPMD from the sharding constraints; ``apply_moe_exact`` is the dropless serving
path (prefill/decode/rollback bit-consistency).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import shard


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    E = m.num_experts
    keys = jax.random.split(key, 5)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(keys[0], (d, E)) * sc_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (E, d, f)) * sc_in).astype(dtype),
        "w_up": (jax.random.normal(keys[2], (E, d, f)) * sc_in).astype(dtype),
        "w_down": (jax.random.normal(keys[3], (E, f, d)) * sc_out).astype(dtype),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        ks = jax.random.split(keys[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[0], (d, fs)) * sc_in).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (d, fs)) * sc_in).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (fs, d)) * sc_out).astype(dtype),
        }
    return p


def _router(p, m, x2d):
    """x2d: (T, d) -> (weights (T,k), idx (T,k), aux losses)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)                  # (T,k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    # aux: load-balance (Switch) + router z-loss
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)                                 # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
        / jnp.maximum(probs.shape[0], 1), axis=0)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = m.load_balance_loss * lb + m.router_z_loss * z
    return topw, topi, aux


def _dispatch_chunk(p, m, xc, *, dtype):
    """One chunk: (Tc, d) -> (Tc, d) routed-expert output + aux loss."""
    Tc, d = xc.shape
    E, k = m.num_experts, m.top_k
    C = max(1, int(math.ceil(Tc * k / E * m.capacity_factor)))
    topw, topi, aux = _router(p, m, xc)

    flat_e = topi.reshape(-1)                                   # (Tc*k,)
    flat_w = topw.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(Tc), k)
    # position of each assignment within its expert: cumsum of one-hot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (Tc*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot) * onehot        # pos within expert
    flat_pos = jnp.sum(pos, axis=-1)                            # (Tc*k,)
    keep = flat_pos < C
    # scatter tokens into (E, C, d)
    buf = jnp.zeros((E, C, d), dtype)
    src = xc.astype(dtype)[flat_t]                              # (Tc*k, d)
    e_idx = jnp.where(keep, flat_e, E)                          # OOB drop
    buf = buf.at[e_idx, jnp.where(keep, flat_pos, 0)].set(src, mode="drop")
    # experts sharded (E->'model', f->'data'): the dispatch buffer keeps d
    # replicated so the e*d->f contraction is fully local per device
    buf = shard(buf, P("model", None, None))

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, d)
    out_buf = shard(out_buf, P("model", None, None))

    # combine: gather each assignment's output, weight, segment-sum per token
    gathered = out_buf[e_idx.clip(0, E - 1), jnp.where(keep, flat_pos, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = (flat_w * keep).astype(jnp.float32)[:, None]
    out = jnp.zeros((Tc, d), jnp.float32).at[flat_t].add(gathered.astype(jnp.float32) * w)
    return out.astype(xc.dtype), aux


def apply_moe(p, cfg, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Chunked over tokens."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    chunk = min(m.dispatch_chunk, T)
    n = -(-T // chunk)
    pad = n * chunk - T
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    xs = x2d.reshape(n, chunk, d)

    fn = partial(_dispatch_chunk, p, m, dtype=x.dtype)
    if n == 1:
        out, aux = fn(xs[0])
        outs, auxs = out[None], aux[None]
    else:
        _, (outs, auxs) = jax.lax.scan(lambda c, xc: (c, fn(xc)), None, xs)
    out = outs.reshape(n * chunk, d)[:T].reshape(B, S, d)
    aux = jnp.mean(auxs)

    if m.num_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = out + jnp.einsum("bsf,fd->bsd", h, sp["w_down"])
    return out, aux


def apply_moe_exact(p, cfg, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dropless (exact) MoE for the serving path: every token's top-k experts are
    honored regardless of batch composition, so prefill == decode == stepwise
    regeneration. O(T*E) compute — fine at serving scale, never used in training
    or dry-run lowering."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    topw, topi, aux = _router(p, m, x2d)
    E = m.num_experts
    wmat = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], topi].set(topw)            # (T,E) sparse weights
    g = jnp.einsum("td,edf->etf", x2d, p["w_gate"])
    u = jnp.einsum("td,edf->etf", x2d, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    o = jnp.einsum("etf,efd->etd", h, p["w_down"])          # (E,T,d)
    out = jnp.einsum("etd,te->td", o.astype(jnp.float32), wmat)
    out = out.reshape(B, S, d).astype(x.dtype)
    if m.num_shared_experts:
        sp = p["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = out + jnp.einsum("bsf,fd->bsd", h, sp["w_down"])
    return out, aux
