"""Paper Table 1 / Table 4 (Fig 7): contribution of P / S / A combinations."""
from __future__ import annotations

from benchmarks.common import (bench_prompts, csv_row, host_lm, make_retriever,
                               run_requests, speedup_pair, variant_rcfg)
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.serving.engine import ServeEngine

VARIANTS = ["", "p", "s", "a", "ps", "sa", "pa", "psa"]


def run(n_requests: int = 3, retrievers=("edr", "adr", "sr"),
        variants=VARIANTS) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests, seed=5)
        eng = ServeEngine(model, params, cache_window=512)
        b = run_requests(RaLMSeq(eng, retr, variant_rcfg(""), enc), prompts)
        rows.append(csv_row(f"table1/{rname}/B", 1e6 * b["analytic"] / b["n"],
                            "wall=1.00x modeled=1.00x"))
        print(rows[-1])
        for v in variants:
            a = run_requests(RaLMSpec(eng, retr, variant_rcfg(v), enc), prompts)
            rows.append(csv_row(
                f"table1/{rname}/{v.upper() or 'spec'}",
                1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(b, a)} "
                f"mism={a['mismatches']} preserved={a['tokens'] == b['tokens']}"))
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
