"""Paper Table 1 / Table 4 (Fig 7): contribution of P / S / A combinations."""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, bench_prompts, csv_row, host_lm,
                               make_retriever, rows_to_json, run_requests,
                               speedup_pair, variant_rcfg, write_json)
from repro.core.ralmspec import RaLMSeq, RaLMSpec  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402

VARIANTS = ["", "p", "s", "a", "ps", "sa", "pa", "psa"]


def run(n_requests: int = 3, retrievers=("edr", "adr", "sr"),
        variants=VARIANTS) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests, seed=5)
        eng = ServeEngine(model, params, cache_window=512)
        b = run_requests(RaLMSeq(eng, retr, variant_rcfg(""), enc), prompts)
        rows.append(csv_row(f"table1/{rname}/B", 1e6 * b["analytic"] / b["n"],
                            "wall=1.00x modeled=1.00x"))
        print(rows[-1])
        for v in variants:
            a = run_requests(RaLMSpec(eng, retr, variant_rcfg(v), enc), prompts)
            rows.append(csv_row(
                f"table1/{rname}/{v.upper() or 'spec'}",
                1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(b, a)} "
                f"mism={a['mismatches']} preserved={a['tokens'] == b['tokens']}"))
            print(rows[-1])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--retrievers", default="edr,adr,sr",
                    help="comma-separated subset of edr,adr,sr")
    ap.add_argument("--variants", default=",".join(VARIANTS),
                    help="comma-separated P/S/A subsets ('' = plain spec)")
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    rows = run(args.requests, tuple(args.retrievers.split(",")),
               args.variants.split(","))
    if args.json is not None:
        write_json("ablation", {
            "config": dict(requests=args.requests,
                           retrievers=args.retrievers,
                           variants=args.variants, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)


if __name__ == "__main__":
    main()
