"""Async (pipelined) fleet rounds vs synchronous fleet rounds.

    PYTHONPATH=src python benchmarks/bench_async_fleet.py --retriever edr \
        --concurrency 1,4 --requests 8 --max-new 32 --json

For each retriever (EDR/ADR/SR) and concurrency level c, serves the same
request set through a c-slot fleet twice — synchronous rounds
(speculate, then wait out the merged verification KB call) and async rounds
(submit the call to a worker thread and immediately speculate the next
lockstep stride, keeping fully-verified slots' overlapped work as a carry) —
and reports both timelines:

  * modeled — the paper-hardware §A.1 batched-retrieval shape, where an
    overlapped round pays ``max(a_overlap, b)`` instead of ``a_overlap' + b``
    (the paper's §4 ideal, fleet-wide). This is where the async win lives:
    EDR's expensive verification hides behind the next stride, so modeled
    speedup > 1 whenever carries survive. ADR — where +A hurts in the
    paper (Table 4) — is protected twice: the adaptive gate
    (``async_gate_ratio``) closes when its probe is genuinely cheap next to
    a stride, and the window bound keeps any overlap its batched
    linear-intercept b_model does open from regressing.
  * wall — this (1-core) container's clock, where the worker thread contends
    with speculation for the same core; reported alongside, as everywhere.
    Wall numbers are medians over ``--wall-repeats`` full passes on the
    monotonic clock (common.measure_wall), and the async rows carry the
    MEASURED overlap ledger from FleetServer: ``verify_wall_s`` (worker-side
    span of the merged KB calls), ``overlap_wall_s`` (main-thread span of the
    overlapped strides), and ``measured_overlap_s`` — the monotonic-clock
    INTERSECTION of the two, i.e. demonstrated (not modeled) concurrency
    between the BLAS/device scan and the LM stride. numpy/XLA release the
    GIL for the heavy ops, so the intersection is real parallelism even on
    one core.

Where the measured (not just modeled) async win comes from on one core:
while the merged call is in flight the fleet speculates PAST the next
stride (``FleetServer._overlap_speculate``'s in-flight extension), so
surviving deep carries collapse whole future rounds into one fat merged
verification. The KB scan is memory-bandwidth-bound — the KB matrix
streams through once per call, near-constant in batch width (the paper's
§A.1 shape, real on CPU) — so fewer merged calls is genuinely less work,
not just rearranged work. Carries only survive when speculation is right;
``--shared-cache`` (the PR-6 cross-request tier, symmetric across both
modes, outputs still verified) supplies that accuracy, and the committed
run uses it. ``--kb-latency`` adds a deterministic per-call service
latency (remote/disk KB regime): pure idle the async worker hides behind
deep speculation while sync pays it serially per round.

``--json`` emits BENCH_async_fleet.json (benchmarks/common.py shared flag)
with per-(retriever, concurrency) rows plus carry statistics, so the perf
trajectory is tracked from this PR on.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig  # noqa: E402
from repro.core.cache import SharedRetrievalCache  # noqa: E402
from repro.launch.serve import build_stack, make_server  # noqa: E402
from repro.retrieval.faults import FaultSpec, inject_faults  # noqa: E402
from repro.training.data import make_queries  # noqa: E402

from common import add_json_arg, measure_wall, warm_engine, write_json  # noqa: E402


def serve_all(fleet, prompts, c):
    """Groups of c through one FleetServer; returns aggregate ledgers."""
    agg = dict(modeled=0.0, wall=0.0, tokens=0, kb_calls=0, rounds=0,
               carry_steps=0, carry_invalidations=0, mismatches=0,
               verify_wall=0.0, overlap_wall=0.0, measured_overlap=0.0)
    toks = []
    for i in range(0, len(prompts), c):
        fr = fleet.serve(prompts[i:i + c])
        agg["modeled"] += fr.analytic_time
        agg["wall"] += fr.wall_time
        agg["tokens"] += fr.total_tokens
        agg["kb_calls"] += fr.kb_calls
        agg["rounds"] += fr.rounds
        agg["verify_wall"] += fr.verify_wall_s
        agg["overlap_wall"] += fr.overlap_wall_s
        agg["measured_overlap"] += fr.measured_overlap_s
        for r in fr.results:
            agg["carry_steps"] += r.carry_steps
            agg["carry_invalidations"] += r.carry_invalidations
            agg["mismatches"] += r.mismatches
            toks.append(tuple(r.tokens))
    agg["outputs"] = toks
    return agg


AUTO_N_DOCS = {"edr": 300_000, "adr": 60_000, "sr": 30_000}


def bench_one(retr_name, levels, args):
    # --n-docs 0 = auto: EDR gets the retrieval-heavy KB the paper's regime
    # needs (verification >> a speculation sub-step, so the overlap window
    # admits whole strides); ADR/SR stay at sizes where their per-query probe
    # cost is comparable to the LM stride — ADR's point here is the gate
    # closing, not a giant KB
    n_docs = args.n_docs or AUTO_N_DOCS[retr_name]
    stack = build_stack(
        retr_name, n_docs=n_docs, enc_dim=args.enc_dim,
        d_model=args.d_model,
        rcfg=RaLMConfig(max_new_tokens=args.max_new,
                        speculation_stride=args.stride,
                        prefetch_top_k=20 if "p" in args.variant else 1,
                        use_os3="s" in args.variant,
                        async_gate_ratio=args.gate_ratio))
    docs, retr, rcfg = stack.docs, stack.retriever, stack.rcfg
    if args.kb_latency > 0 and hasattr(retr, "backend"):
        # constant KB service latency (deterministic spike-on-every-call via
        # the PR-8 fault harness; latency-only, so outputs stay
        # byte-identical). This models the production regime the paper
        # assumes — a remote/disk-backed KB whose calls have genuine idle
        # service time. It matters for the WALL columns on boxes where the
        # in-process scan is compute-bound: two CPU-bound threads on one
        # core only time-slice, but service latency is real idle time the
        # async worker provably hides by speculating while the call is in
        # flight (the measured-overlap ledger shows the reclaimed span).
        # Both modes pay the same per-call latency. Dense retrievers only:
        # their backend fires ONCE per merged call; SR's sparse KB scores a
        # merged call's queries one by one, so a per-scan sleep there would
        # multiply by the query count instead of modeling a service RTT.
        inject_faults(retr, FaultSpec(p_spike=1.0, spike_s=args.kb_latency))
    elif args.kb_latency > 0:
        print(f"[{retr_name}] --kb-latency skipped (sparse KB scores "
              "per-query; a per-scan sleep would not model one service RTT "
              "per merged call)")
    prompts = [(q * 12)[:48] for q in make_queries(docs, args.requests)]
    print(f"\n== {retr_name.upper()}  ({n_docs} docs, enc_dim="
          f"{args.enc_dim}, {args.requests} requests, max_new={args.max_new},"
          f" s={args.stride}) ==")
    print(f"{'conc':>4} {'sync modeled':>13} {'async modeled':>14} "
          f"{'speedup':>8} {'sync wall':>10} {'async wall':>11} "
          f"{'overlap':>9} {'carried':>8} {'invalid':>8}")
    rows = {}
    for c in levels:
        stack.engine = None             # fresh c-slot engine for this width
        # with --shared-cache each mode gets its OWN fresh tier, warmed by
        # its own warmup serve — the PR-6 cross-request speculation source,
        # symmetric across modes (speculation-only, outputs still verified)
        mk_shared = ((lambda: SharedRetrievalCache(
            capacity=args.shared_capacity)) if args.shared_cache
            else (lambda: None))
        # median-of-repeats on the monotonic clock; the warmup serve inside
        # the sync block amortizes jit + stats calibration for both modes
        # (the two modes share one engine: make_server caches it on the stack)
        stack.shared_cache = mk_shared()
        with make_server(stack, scheduler="fixed", n_slots=c,
                         async_fleet=False) as sync:
            warm_engine(sync.engine, rcfg)
            sync.serve(prompts[:c])        # warmup: jit + stats calibration
            s_wall, _, s = measure_wall(lambda: serve_all(sync, prompts, c),
                                        repeats=args.wall_repeats, warmup=0)
        stack.shared_cache = mk_shared()
        with make_server(stack, scheduler="fixed", n_slots=c,
                         async_fleet=True) as a_fleet:
            # async gets the same warmup the sync block got: its fat carried
            # rounds hit jit shapes (wider verify batches, overlap strides)
            # the sync pass never compiles, and the gate's EMAs need a
            # calibration serve — without this the first measured repeat
            # pays compile time the sync column never paid
            a_fleet.serve(prompts[:c])
            a_wall, _, a = measure_wall(lambda: serve_all(a_fleet, prompts, c),
                                        repeats=args.wall_repeats, warmup=0)
        assert a["outputs"] == s["outputs"], \
            f"{retr_name} c={c}: async fleet changed outputs"
        sp_m = s["modeled"] / max(a["modeled"], 1e-9)
        sp_w = s_wall / max(a_wall, 1e-9)
        print(f"{c:>4} {s['modeled']:>12.2f}s {a['modeled']:>13.2f}s "
              f"{sp_m:>7.2f}x {s_wall:>9.2f}s {a_wall:>10.2f}s "
              f"{a['measured_overlap']:>8.2f}s "
              f"{a['carry_steps']:>8} {a['carry_invalidations']:>8}")
        rows[str(c)] = {
            "sync_modeled_s": s["modeled"], "async_modeled_s": a["modeled"],
            "sync_wall_s": s_wall, "async_wall_s": a_wall,
            "modeled_speedup": sp_m, "wall_speedup": sp_w,
            # measured-overlap ledger (last async repeat, monotonic clock):
            # measured_overlap_s is the span INTERSECTION of the worker's KB
            # call and the main thread's overlapped stride — demonstrated,
            # not modeled, concurrency
            "verify_wall_s": a["verify_wall"],
            "overlap_wall_s": a["overlap_wall"],
            "measured_overlap_s": a["measured_overlap"],
            "overlap_fraction": (a["measured_overlap"]
                                 / max(a["verify_wall"], 1e-9)),
            "tokens": a["tokens"], "rounds": a["rounds"],
            "kb_calls": a["kb_calls"], "carry_steps": a["carry_steps"],
            "carry_invalidations": a["carry_invalidations"],
            "mismatches": a["mismatches"],
        }
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", default="edr", help="edr | adr | sr | all")
    ap.add_argument("--concurrency", default="1,4",
                    help="comma-separated fleet sizes")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--n-docs", type=int, default=0,
                    help="KB size; 0 = auto per retriever "
                         "(EDR 300k, ADR 60k, SR 30k)")
    ap.add_argument("--enc-dim", type=int, default=512,
                    help="dense embedding dim (sets EDR's verification cost)")
    ap.add_argument("--d-model", type=int, default=64,
                    help="host-LM width (sets the speculation-step cost)")
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--variant", default="p",
                    help="subset of 'ps' layered under the async rounds: "
                         "prefetching (cache warming -> higher full-stride "
                         "match rate -> more surviving carries) and OS^3 "
                         "(stride from the async objective). The paper "
                         "evaluates +A inside P+S+A; 'p' is the default")
    ap.add_argument("--gate-ratio", type=float,
                    default=RaLMConfig().async_gate_ratio,
                    help="adaptive overlap gate: overlap only when "
                         "b_est > ratio * a_est")
    ap.add_argument("--wall-repeats", type=int, default=3,
                    help="median-of-N full passes for the wall columns "
                         "(common.measure_wall)")
    ap.add_argument("--kb-latency", type=float, default=0.0,
                    help="constant KB service latency in seconds per scan "
                         "(deterministic latency-only fault injection; "
                         "models a remote/disk-backed KB). 0 = in-process "
                         "scan only")
    ap.add_argument("--shared-cache", action="store_true",
                    help="give each mode a fresh SharedRetrievalCache tier "
                         "(cross-request speculation source; raises the "
                         "full-stride match rate so deep carries survive)")
    ap.add_argument("--shared-capacity", type=int, default=4096)
    add_json_arg(ap)
    args = ap.parse_args()
    levels = [int(x) for x in args.concurrency.split(",")]
    names = ["edr", "adr", "sr"] if args.retriever == "all" else [args.retriever]
    results = {name: bench_one(name, levels, args) for name in names}
    if args.json is not None:
        write_json("async_fleet", {
            "config": {"concurrency": levels, "requests": args.requests,
                       "max_new": args.max_new, "n_docs": args.n_docs,
                       "auto_n_docs": AUTO_N_DOCS,
                       "enc_dim": args.enc_dim, "d_model": args.d_model,
                       "stride": args.stride, "variant": args.variant,
                       "gate_ratio": args.gate_ratio,
                       "wall_repeats": args.wall_repeats,
                       "kb_latency_s": args.kb_latency,
                       "shared_cache": bool(args.shared_cache)},
            "results": results}, args.json)


if __name__ == "__main__":
    main()
