"""Paper Table 2: prefetch size 20 vs 256 (larger prefetch can hurt — higher
retrieval cost per verification outweighs the hit-rate gain for cheap retrievers)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import (bench_prompts, csv_row, host_lm, make_retriever,
                               run_requests, speedup_pair, variant_rcfg)
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.serving.engine import ServeEngine


def run(n_requests: int = 3, retrievers=("edr", "adr", "sr")) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests, seed=7)
        eng = ServeEngine(model, params, cache_window=512)
        b = run_requests(RaLMSeq(eng, retr, variant_rcfg(""), enc), prompts)
        for size in (20, 256):
            rcfg = dataclasses.replace(variant_rcfg("p"), prefetch_top_k=size)
            a = run_requests(RaLMSpec(eng, retr, rcfg, enc), prompts)
            rows.append(csv_row(
                f"table2/{rname}/P({size})", 1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(b, a)} mism={a['mismatches']}"))
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
