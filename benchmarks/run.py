"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (and progress to stderr).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 requests per config instead of the full counts")
    ap.add_argument("--only", default=None,
                    help="serving|ablation|prefetch|stride|knnlm|batch|roofline")
    args = ap.parse_args()
    n = 2 if args.quick else 4
    n_small = 2 if args.quick else 3

    from benchmarks import (bench_ablation, bench_batch_retrieval, bench_knnlm,
                            bench_prefetch, bench_serving, bench_stride,
                            roofline)

    suites = {
        "batch": lambda: bench_batch_retrieval.run(),
        "serving": lambda: bench_serving.run(n_requests=n),
        "ablation": lambda: bench_ablation.run(n_requests=n_small),
        "prefetch": lambda: bench_prefetch.run(n_requests=n_small),
        "stride": lambda: bench_stride.run(n_requests=n_small),
        "knnlm": lambda: bench_knnlm.run(n_requests=n_small),
        "roofline": lambda: roofline.run(),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            all_rows.extend(fn() or [])
        except Exception as e:  # noqa: BLE001
            import traceback
            print(f"# {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
            continue
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
