"""Paper Figure 4 / Tables 6-8: RaLMSeq vs RaLMSpec vs RaLMSpec+PSA per retriever,
with the G (generation) / R (retrieval) latency decomposition."""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, bench_prompts, csv_row, host_lm,
                               make_retriever, rows_to_json, run_requests,
                               speedup_pair, variant_rcfg, write_json)
from repro.core.ralmspec import RaLMSeq, RaLMSpec  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402


def run(n_requests: int = 4, retrievers=("edr", "adr", "sr")) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests)
        eng = ServeEngine(model, params, cache_window=512)
        base = None
        for mname, server in [
            ("RaLMSeq", RaLMSeq(eng, retr, variant_rcfg(""), enc)),
            ("RaLMSpec", RaLMSpec(eng, retr, variant_rcfg(""), enc)),
            ("RaLMSpec+PSA", RaLMSpec(eng, retr, variant_rcfg("psa"), enc)),
            ("RaLMSpec+PSA+sess", RaLMSpec(eng, retr, variant_rcfg("psa"), enc,
                                           persistent_cache=True)),
        ]:
            a = run_requests(server, prompts)
            if base is None:
                base = a
            rows.append(csv_row(
                f"fig4/{rname}/{mname}", 1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(base, a)} G={a['gen']:.2f}s R={a['retr']:.2f}s "
                f"preserved={a['tokens'] == base['tokens']}"))
            print(rows[-1])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--retrievers", default="edr,adr,sr",
                    help="comma-separated subset of edr,adr,sr")
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    rows = run(args.requests, tuple(args.retrievers.split(",")))
    if args.json is not None:
        write_json("serving", {
            "config": dict(requests=args.requests,
                           retrievers=args.retrievers, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)


if __name__ == "__main__":
    main()
