"""Paper Figure 4 / Tables 6-8: RaLMSeq vs RaLMSpec vs RaLMSpec+PSA per retriever,
with the G (generation) / R (retrieval) latency decomposition."""
from __future__ import annotations

from benchmarks.common import (bench_prompts, csv_row, host_lm, make_retriever,
                               run_requests, speedup_pair, variant_rcfg)
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.serving.engine import ServeEngine


def run(n_requests: int = 4, retrievers=("edr", "adr", "sr")) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests)
        eng = ServeEngine(model, params, cache_window=512)
        base = None
        for mname, server in [
            ("RaLMSeq", RaLMSeq(eng, retr, variant_rcfg(""), enc)),
            ("RaLMSpec", RaLMSpec(eng, retr, variant_rcfg(""), enc)),
            ("RaLMSpec+PSA", RaLMSpec(eng, retr, variant_rcfg("psa"), enc)),
            ("RaLMSpec+PSA+sess", RaLMSpec(eng, retr, variant_rcfg("psa"), enc,
                                           persistent_cache=True)),
        ]:
            a = run_requests(server, prompts)
            if base is None:
                base = a
            rows.append(csv_row(
                f"fig4/{rname}/{mname}", 1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(base, a)} G={a['gen']:.2f}s R={a['retr']:.2f}s "
                f"preserved={a['tokens'] == base['tokens']}"))
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
