"""Paper Figure 6 / §A.1: per-query latency vs batch size for the three retrievers —
the structural fact batched verification exploits."""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, csv_row, make_retriever,
                               rows_to_json, write_json)


def _time_batches(retr, make_queries_fn, sizes=(1, 2, 4, 8, 16), reps: int = 3):
    out = {}
    qs = make_queries_fn(max(sizes))
    retr.retrieve(qs[:1] if not isinstance(qs, list) else qs[:1], 4)  # warm
    for b in sizes:
        t0 = time.perf_counter()
        for _ in range(reps):
            retr.retrieve(qs[:b], 4)
        out[b] = (time.perf_counter() - t0) / reps / b
    return out


def run(retrievers=("edr", "adr", "sr"), sizes=(1, 2, 4, 8, 16),
        reps: int = 3) -> list:
    rows = []
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        if rname == "sr":
            make_q = lambda n: [docs[i][:8] for i in range(n)]
        else:
            make_q = lambda n: np.stack([enc.encode(docs[i][:10])
                                         for i in range(n)])
        per_q = _time_batches(retr, make_q, sizes=sizes, reps=reps)
        big = max(sizes)
        ratio = per_q[sizes[0]] / max(per_q[big], 1e-12)
        for b, t in per_q.items():
            rows.append(csv_row(
                f"fig6/{rname}/batch{b}", 1e6 * t,
                f"perq_speedup_vs_b{sizes[0]}="
                f"{per_q[sizes[0]] / max(t, 1e-12):.2f}x"))
            print(rows[-1])
        print(f"  -> {rname}: batch-{big} is {ratio:.1f}x cheaper per query "
              f"than batch-{sizes[0]}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--retrievers", default="edr,adr,sr",
                    help="comma-separated subset of edr,adr,sr")
    ap.add_argument("--sizes", default="1,2,4,8,16",
                    help="comma-separated query batch sizes")
    ap.add_argument("--reps", type=int, default=3)
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    rows = run(tuple(args.retrievers.split(",")),
               tuple(int(x) for x in args.sizes.split(",")), args.reps)
    if args.json is not None:
        write_json("batch_retrieval", {
            "config": dict(retrievers=args.retrievers, sizes=args.sizes,
                           reps=args.reps, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)


if __name__ == "__main__":
    main()
