"""Paper Figure 6 / §A.1: per-query latency vs batch size for the three retrievers —
the structural fact batched verification exploits."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, dense_stack, make_retriever, sparse_stack


def _time_batches(retr, make_queries_fn, sizes=(1, 2, 4, 8, 16), reps: int = 3):
    out = {}
    qs = make_queries_fn(max(sizes))
    retr.retrieve(qs[:1] if not isinstance(qs, list) else qs[:1], 4)  # warm
    for b in sizes:
        t0 = time.perf_counter()
        for _ in range(reps):
            retr.retrieve(qs[:b], 4)
        out[b] = (time.perf_counter() - t0) / reps / b
    return out


def run() -> list:
    rows = []
    for rname in ("edr", "adr", "sr"):
        docs, enc, retr = make_retriever(rname)
        if rname == "sr":
            make_q = lambda n: [docs[i][:8] for i in range(n)]
        else:
            make_q = lambda n: np.stack([enc.encode(docs[i][:10])
                                         for i in range(n)])
        per_q = _time_batches(retr, make_q)
        ratio = per_q[1] / max(per_q[16], 1e-12)
        for b, t in per_q.items():
            rows.append(csv_row(f"fig6/{rname}/batch{b}", 1e6 * t,
                                f"perq_speedup_vs_b1={per_q[1] / max(t, 1e-12):.2f}x"))
            print(rows[-1])
        print(f"  -> {rname}: batch-16 is {ratio:.1f}x cheaper per query than batch-1")
    return rows


if __name__ == "__main__":
    run()
