"""Continuous vs fixed-group fleet: throughput and latency vs arrival rate.

    PYTHONPATH=src python benchmarks/bench_continuous.py --retriever edr \
        --slots 4 --requests 12 --max-new 32 --rates 0,2,8

For each arrival rate R (Poisson, requests per modeled second; R=0 means every
request arrives at t=0 — the saturated regime), the same request set — with
heterogeneous per-request token budgets, cycling short/medium/long — is served
two ways over S engine slots:

  * continuous — ContinuousFleetServer: requests are admitted into slots the
    moment slots free up mid-flight; short requests retire early and their
    slots immediately take queued work, so no slot idles while work waits.
  * fixed      — FleetServer groups of S in arrival order: a group launches
    once its last member has arrived and the previous group has drained, and
    every member occupies its slot until the whole group finishes (idle-slot
    waste: short requests pad out to the group's longest).

Reported per scheduler: modeled tokens/s over the makespan (the §A.1
paper-hardware batched-retrieval timeline; wall-clock alongside) and modeled
p50/p99 request latency including queueing delay. At high arrival rate the
queue never starves, so continuous >= fixed in modeled throughput — the gap is
exactly the idle-slot waste the fixed grouping pays on heterogeneous lengths.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig  # noqa: E402
from repro.launch.serve import build_stack, make_arrivals, make_server  # noqa: E402
from repro.serving.continuous import as_requests, percentile  # noqa: E402
from repro.training.data import make_queries  # noqa: E402

from common import add_json_arg, warm_engine, write_json  # noqa: E402

# long/short interleaved: arrival-order groups of S mix lengths, so fixed
# grouping pads every short request out to a long neighbor's finish — the
# idle-slot waste continuous batching exists to reclaim
BUDGET_CYCLE = (1.0, 0.25, 1.0, 0.5)


def request_budgets(n: int, max_new: int):
    return [max(4, int(round(max_new * BUDGET_CYCLE[i % len(BUDGET_CYCLE)])))
            for i in range(n)]


def serve_fixed(fleet, prompts, arrivals, budgets, slots: int):
    """Static batching on the arrival timeline: groups of `slots` in arrival
    order; a group launches at max(prev group drain, its last arrival) and its
    members all finish when the group does."""
    order = sorted(range(len(prompts)), key=lambda i: (arrivals[i], i))
    clock, lat, tokens, wall = 0.0, {}, 0, 0.0
    for g in range(0, len(order), slots):
        members = order[g:g + slots]
        start = max(clock, max(arrivals[i] for i in members))
        fr = fleet.serve([prompts[i] for i in members],
                         max_new=[budgets[i] for i in members])
        clock = start + fr.analytic_time
        wall += fr.wall_time
        tokens += fr.total_tokens
        for i in members:
            lat[i] = clock - arrivals[i]
    return dict(makespan=clock, wall=wall, tokens=tokens,
                lats=[lat[i] for i in range(len(prompts))])


def bench_one(retr_name: str, rates, slots: int, n_requests: int, max_new: int,
              n_docs: int, stride: int, seed: int):
    stack = build_stack(retr_name, n_docs=n_docs,
                        rcfg=RaLMConfig(max_new_tokens=max_new,
                                        speculation_stride=stride))
    rcfg = stack.rcfg
    prompts = [(q * 12)[:48] for q in make_queries(stack.docs, n_requests)]
    budgets = request_budgets(n_requests, max_new)
    print(f"\n== {retr_name.upper()}  ({n_docs} docs, {n_requests} requests, "
          f"{slots} slots, budgets {min(budgets)}..{max(budgets)} tok, "
          f"s={stride}) ==")
    print(f"{'rate':>6} {'sched':>11} {'tok/s (modeled)':>16} "
          f"{'tok/s (wall)':>13} {'p50':>8} {'p99':>8} {'makespan':>9}")
    rows = []
    # context managers: the (potential) verification workers are released
    # even if a serve raises mid-sweep
    with make_server(stack, scheduler="continuous", n_slots=slots) as cont, \
            make_server(stack, scheduler="fixed", n_slots=slots) as fleet:
        warm_engine(stack.engine, rcfg)          # one engine, shared by both
        cont.serve(as_requests(prompts[:slots]))  # warmup: jit + stats calibration
        for rate in rates:
            arrivals = make_arrivals(n_requests, rate, seed=seed)
            cr = cont.serve(as_requests(prompts, arrivals, budgets))
            fx = serve_fixed(fleet, prompts, arrivals, budgets, slots)
            tp_c, tp_f = cr.throughput(), fx["tokens"] / max(fx["makespan"], 1e-9)
            tag = f"{rate:g}" if rate > 0 else "sat"
            print(f"{tag:>6} {'continuous':>11} {tp_c:>16.1f} "
                  f"{cr.throughput(modeled=False):>13.1f} {cr.p50:>7.2f}s "
                  f"{cr.p99:>7.2f}s {cr.analytic_time:>8.2f}s")
            print(f"{'':>6} {'fixed':>11} {tp_f:>16.1f} "
                  f"{fx['tokens'] / max(fx['wall'], 1e-9):>13.1f} "
                  f"{percentile(fx['lats'], 50):>7.2f}s "
                  f"{percentile(fx['lats'], 99):>7.2f}s {fx['makespan']:>8.2f}s")
            print(f"{'':>6} {'':>11} continuous/fixed modeled throughput "
                  f"x{tp_c / max(tp_f, 1e-9):.2f}")
            rows.append(dict(
                rate=rate,
                continuous=dict(tokps_modeled=tp_c,
                                tokps_wall=cr.throughput(modeled=False),
                                p50_s=cr.p50, p99_s=cr.p99,
                                makespan_s=cr.analytic_time),
                fixed=dict(tokps_modeled=tp_f,
                           tokps_wall=fx["tokens"] / max(fx["wall"], 1e-9),
                           p50_s=percentile(fx["lats"], 50),
                           p99_s=percentile(fx["lats"], 99),
                           makespan_s=fx["makespan"])))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", default="edr", help="edr | adr | sr | all")
    ap.add_argument("--rates", default="0,2,8",
                    help="comma-separated Poisson arrival rates (req per "
                         "modeled second); 0 = all requests at t=0 (saturated)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    add_json_arg(ap)
    args = ap.parse_args()
    rates = [float(x) for x in args.rates.split(",")]
    names = ["edr", "adr", "sr"] if args.retriever == "all" else [args.retriever]
    results = {}
    for name in names:
        results[name] = bench_one(name, rates, args.slots, args.requests,
                                  args.max_new, args.n_docs, args.stride,
                                  args.seed)
    if args.json is not None:
        write_json("continuous", {
            "config": dict(rates=rates, slots=args.slots,
                           requests=args.requests, max_new=args.max_new,
                           n_docs=args.n_docs, stride=args.stride,
                           seed=args.seed),
            "results": results}, args.json)


if __name__ == "__main__":
    main()
