"""Fleet-scale shared speculation cache tier on Zipf-skewed query streams.

    PYTHONPATH=src python benchmarks/bench_shared_cache.py --retriever edr \
        --slots 4 --requests 16 --distinct 6 --zipf 1.1 --rates 0,2,8

At fleet scale query popularity is heavy-tailed: a few hot prompts recur
constantly. This bench draws each request's prompt from ``--distinct``
distinct prompts with Zipf weights (P(rank r) ~ 1/r^zipf) and serves the
stream through ContinuousFleetServer twice per arrival rate:

  * off — per-request speculation caches only (the paper's setting),
  * on  — the SharedRetrievalCache tier in front of the KB (exact-hit on
          query bytes, then approximate-hit on embedding inner product),
          shared by every request; plus the always-on in-round dedup of
          identical queries inside each merged verification call.

Reported per mode: modeled p50/p99 request latency (queueing included),
modeled makespan/throughput, KB calls and KB rows actually retrieved, the
dedup ledger (merged rows sent vs rows saved by the in-round collapse), and
the shared tier's hit rates. Outputs are asserted byte-identical between the
two modes — the tier only steers speculation; batched verification still
confirms every document (tests/test_shared_cache.py holds the same claim
against RaLMSeq per retriever and serving path).
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig  # noqa: E402
from repro.core.cache import SharedRetrievalCache  # noqa: E402
from repro.launch.serve import build_stack, make_arrivals, make_server  # noqa: E402
from repro.serving.continuous import as_requests  # noqa: E402
from repro.training.data import make_queries  # noqa: E402

from common import add_json_arg, add_tiny_arg, warm_engine, write_json  # noqa: E402


def zipf_stream(docs, n_requests: int, n_distinct: int, alpha: float,
                seed: int):
    """Draw ``n_requests`` prompts from ``n_distinct`` distinct ones with
    P(rank r) ~ 1/r^alpha — the heavy-tailed popularity the tier amortizes."""
    distinct = [(q * 12)[:48] for q in make_queries(docs, n_distinct)]
    w = 1.0 / np.arange(1, n_distinct + 1) ** alpha
    picks = np.random.default_rng(seed).choice(n_distinct, size=n_requests,
                                               p=w / w.sum())
    return [distinct[i] for i in picks], picks.tolist()


def serve_mode(server, prompts, arrivals, shared):
    cr = server.serve(as_requests(prompts, arrivals))
    cell = dict(p50_s=cr.p50, p99_s=cr.p99, makespan_s=cr.analytic_time,
                tokps_modeled=cr.throughput(),
                tokps_wall=cr.throughput(modeled=False),
                kb_calls=cr.kb_calls, kb_queries=cr.kb_queries,
                merged_rows=cr.merged_rows,
                merged_rows_saved=cr.merged_rows_saved)
    if shared is not None:
        st = shared.stats()
        cell.update(shared_hit_rate=st["hit_rate"],
                    shared_hits_exact=st["hits_exact"],
                    shared_hits_approx=st["hits_approx"],
                    shared_size=st["size"])
    return cell, [tuple(r.tokens) for r in cr.results]


def bench_one(retr_name: str, rates, args):
    stack = build_stack(retr_name, n_docs=args.n_docs,
                        rcfg=RaLMConfig(max_new_tokens=args.max_new,
                                        speculation_stride=args.stride))
    rcfg = stack.rcfg
    prompts, picks = zipf_stream(stack.docs, args.requests, args.distinct,
                                 args.zipf, args.seed)
    print(f"\n== {retr_name.upper()}  ({args.n_docs} docs, {args.requests} "
          f"requests over {args.distinct} distinct prompts, zipf "
          f"{args.zipf:g}, {args.slots} slots, {args.max_new} tok) ==")
    print(f"{'rate':>6} {'shared':>7} {'p50':>8} {'p99':>8} {'makespan':>9} "
          f"{'kb rows':>8} {'dedup saved':>12} {'hit rate':>9}")
    rows = []
    # context managers: worker threads released even if a serve raises
    stack.shared_cache = None
    with make_server(stack, scheduler="continuous",
                     n_slots=args.slots) as off_server:
        warm_engine(off_server.engine, rcfg)
        off_server.serve(as_requests(prompts[:args.slots]))  # warmup: jit + stats
        for rate in rates:
            arrivals = make_arrivals(args.requests, rate, seed=args.seed)
            off, toks_off = serve_mode(off_server, prompts, arrivals, None)
            shared = SharedRetrievalCache(capacity=args.shared_capacity)
            stack.shared_cache = shared
            with make_server(stack, scheduler="continuous",
                             n_slots=args.slots) as on_server:
                on, toks_on = serve_mode(on_server, prompts, arrivals, shared)
            assert toks_on == toks_off, \
                "shared cache changed outputs (preservation violated)"
            tag = f"{rate:g}" if rate > 0 else "sat"
            for label, cell in (("off", off), ("on", on)):
                hr = (f"{cell['shared_hit_rate']:>8.0%}"
                      if "shared_hit_rate" in cell else f"{'-':>8}")
                print(f"{tag if label == 'off' else '':>6} {label:>7} "
                      f"{cell['p50_s']:>7.2f}s {cell['p99_s']:>7.2f}s "
                      f"{cell['makespan_s']:>8.2f}s {cell['kb_queries']:>8} "
                      f"{cell['merged_rows_saved']:>12} {hr}")
            rows.append(dict(rate=rate, off=off, on=on,
                             outputs_identical=True))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", default="edr", help="edr | adr | sr | all")
    ap.add_argument("--rates", default="0,2,8",
                    help="comma-separated Poisson arrival rates (req per "
                         "modeled second); 0 = all requests at t=0 (saturated)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--distinct", type=int, default=6,
                    help="distinct prompts behind the Zipf draw")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="Zipf skew alpha (P(rank r) ~ 1/r^alpha)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--shared-capacity", type=int, default=65536)
    ap.add_argument("--seed", type=int, default=0)
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    if args.tiny:       # CI bench-smoke sizes: end-to-end in seconds
        args.n_docs, args.requests, args.distinct = 800, 5, 2
        args.slots, args.max_new, args.rates = 2, 8, "0"
    rates = [float(x) for x in args.rates.split(",")]
    names = ["edr", "adr", "sr"] if args.retriever == "all" else [args.retriever]
    results = {}
    for name in names:
        results[name] = bench_one(name, rates, args)
    if args.json is not None:
        write_json("shared_cache", {
            "config": dict(rates=rates, slots=args.slots,
                           requests=args.requests, distinct=args.distinct,
                           zipf=args.zipf, max_new=args.max_new,
                           n_docs=args.n_docs, stride=args.stride,
                           shared_capacity=args.shared_capacity,
                           seed=args.seed),
            "results": results}, args.json)


if __name__ == "__main__":
    main()
