"""Paper Table 5: fixed speculation strides s=2,4,8 vs OS^3 — expensive retrievers
prefer big strides, cheap ones small strides, OS3 adapts."""
from __future__ import annotations

import dataclasses

from benchmarks.common import (bench_prompts, csv_row, host_lm, make_retriever,
                               run_requests, speedup_pair, variant_rcfg)
from repro.core.ralmspec import RaLMSeq, RaLMSpec
from repro.serving.engine import ServeEngine


def run(n_requests: int = 3, retrievers=("edr", "adr", "sr")) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests, seed=11)
        eng = ServeEngine(model, params, cache_window=512)
        b = run_requests(RaLMSeq(eng, retr, variant_rcfg(""), enc), prompts)
        for label, rcfg in (
            [(f"S={s}", dataclasses.replace(variant_rcfg(""),
                                            speculation_stride=s))
             for s in (2, 4, 8)] + [("OS3", variant_rcfg("s"))]
        ):
            a = run_requests(RaLMSpec(eng, retr, rcfg, enc), prompts)
            rows.append(csv_row(
                f"table5/{rname}/{label}", 1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(b, a)} mism={a['mismatches']}"))
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
