"""Paper Table 5: fixed speculation strides s=2,4,8 vs OS^3 — expensive retrievers
prefer big strides, cheap ones small strides, OS3 adapts."""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, bench_prompts, csv_row, host_lm,
                               make_retriever, rows_to_json, run_requests,
                               speedup_pair, variant_rcfg, write_json)
from repro.core.ralmspec import RaLMSeq, RaLMSpec  # noqa: E402
from repro.serving.engine import ServeEngine  # noqa: E402


def run(n_requests: int = 3, retrievers=("edr", "adr", "sr")) -> list:
    rows = []
    cfg, model, params = host_lm()
    for rname in retrievers:
        docs, enc, retr = make_retriever(rname)
        prompts = bench_prompts(docs, n_requests, seed=11)
        eng = ServeEngine(model, params, cache_window=512)
        b = run_requests(RaLMSeq(eng, retr, variant_rcfg(""), enc), prompts)
        for label, rcfg in (
            [(f"S={s}", dataclasses.replace(variant_rcfg(""),
                                            speculation_stride=s))
             for s in (2, 4, 8)] + [("OS3", variant_rcfg("s"))]
        ):
            a = run_requests(RaLMSpec(eng, retr, rcfg, enc), prompts)
            rows.append(csv_row(
                f"table5/{rname}/{label}", 1e6 * a["analytic"] / a["n"],
                f"{speedup_pair(b, a)} mism={a['mismatches']}"))
            print(rows[-1])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--retrievers", default="edr,adr,sr",
                    help="comma-separated subset of edr,adr,sr")
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    rows = run(args.requests, tuple(args.retrievers.split(",")))
    if args.json is not None:
        write_json("stride", {
            "config": dict(requests=args.requests,
                           retrievers=args.retrievers, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)


if __name__ == "__main__":
    main()
