"""Paper Figure 5: KNN-LM serving speed-ups (per-token retrieval; spatial-prefetch
cache + token-match verification), k in {1, 8, 64}, fixed stride vs OS^3.

``--mode fleet`` serves KNN-LM through the fleet instead: per-request
KNNLMSeq baseline vs the merged-round serving paths (FleetServer,
ContinuousFleetServer, async two-stage FleetServer) at each ``--concurrency``
level, asserting token-match per request and emitting
``BENCH_knnlm_fleet.json`` — the acceptance artifact for the Workload seam
(fleet KNN-LM >= 1.5x modeled over per-request KNNLMSeq at EDR c >= 4).

``--backend`` routes the EDR datastore scan through the retrieval-backend
layer (numpy / kernel / sharded); ``--mesh-shards N`` forces an N-device host
platform for the sharded backend (applied before jax loads, like
launch/serve.py)."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.retrieval.backends import bootstrap_mesh_shards  # noqa: E402

bootstrap_mesh_shards()                 # before anything imports jax

import dataclasses  # noqa: E402

import jax  # noqa: E402

from benchmarks.common import (VOCAB, add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, csv_row, knn_stack, rows_to_json,
                               run_requests, speedup_pair, write_json)
from repro.configs import RaLMConfig, get_config, reduced  # noqa: E402
from repro.core.knnlm import KNNLMSeq, KNNLMSpec  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.retrieval.retrievers import (ExactDenseRetriever,  # noqa: E402
                                        IVFRetriever)
from repro.serving.batched import BatchedServeEngine  # noqa: E402
from repro.serving.continuous import (ContinuousFleetServer,  # noqa: E402
                                      as_requests)
from repro.serving.engine import ServeEngine  # noqa: E402
from repro.serving.fleet import FleetServer  # noqa: E402


def run(n_requests: int = 3, ks=(1, 8, 64), backend: str = "numpy",
        mesh_shards: int = 0) -> list:
    """``backend`` picks the EDR datastore-scan backend
    (repro.retrieval.backends.BACKENDS, int8 quantized included);
    ``mesh_shards`` caps the sharded shard count (0 = one shard per visible
    device)."""
    rows = []
    cfg = reduced(get_config("knnlm-247m"), layers=2, d_model=128, vocab=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream, enc, ds = knn_stack()
    prompts = [stream[i * 97:i * 97 + 48].tolist() for i in range(n_requests)]
    edr = ExactDenseRetriever(ds, backend=backend, mesh_shards=mesh_shards)
    if backend != "numpy":
        detail = (f"{edr.backend.n_shards} shard(s)"
                  if edr.backend.name.endswith("sharded")
                  else "device-resident KB")
        print(f"EDR datastore backend: {edr.backend.name} ({detail})")
    for rname, retr in [("edr", edr),
                        ("adr", IVFRetriever(ds, n_clusters=128, nprobe=4,
                                             iters=3))]:
        for k in ks:
            base_cfg = RaLMConfig(knnlm=True, knn_k=k, max_new_tokens=48,
                                  speculation_stride=3)
            eng = ServeEngine(model, params, cache_window=256)
            b = run_requests(KNNLMSeq(eng, retr, base_cfg, enc), prompts)
            for label, rc in [("s3", base_cfg),
                              ("OS3", dataclasses.replace(base_cfg, use_os3=True))]:
                a = run_requests(KNNLMSpec(eng, retr, rc, enc), prompts)
                rows.append(csv_row(
                    f"fig5/{rname}/k{k}/{label}", 1e6 * a["analytic"] / a["n"],
                    f"{speedup_pair(b, a)} "
                    f"preserved={a['tokens'] == b['tokens']} "
                    f"mism={a['mismatches']}"))
                print(rows[-1])
    return rows


FLEET_MODES = ("fleet", "continuous", "async")


def run_fleet(concurrency=(1, 2, 4), backend: str = "numpy",
              mesh_shards: int = 0, k: int = 8, max_new: int = 48,
              stride: int = 3) -> dict:
    """Per-request KNNLMSeq vs the three merged-round serving paths, one cell
    per (retriever, mode, concurrency): at level c the SAME c prompts are
    served per-request by KNNLMSeq (modeled time sums — requests back to
    back) and as one group by the c-slot fleet (shared merged-round
    timeline). Speculation batches per-token retrieval into one stride-wide
    call per request per round, and the fleet merges those across slots into
    ONE KB call per round — the modeled speedup grows with c because the
    EDR scan cost is per-call, not per-query."""
    cfg = reduced(get_config("knnlm-247m"), layers=2, d_model=128, vocab=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream, enc, ds = knn_stack()
    retrievers = [("edr", ExactDenseRetriever(ds, backend=backend,
                                              mesh_shards=mesh_shards)),
                  ("adr", IVFRetriever(ds, n_clusters=128, nprobe=4, iters=3))]
    rcfg = RaLMConfig(knnlm=True, knn_k=k, max_new_tokens=max_new,
                      speculation_stride=stride)
    # async two-stage rounds: gate forced open + full-stride overlap so the
    # pipeline actually engages at bench sizes (same knobs as the async tests)
    acfg = dataclasses.replace(rcfg, async_verification=True,
                               async_gate_ratio=0.0, async_min_overlap=stride)
    results = {rname: {m: {} for m in FLEET_MODES} for rname, _ in retrievers}
    seq_eng = ServeEngine(model, params, cache_window=256)
    for c in concurrency:
        prompts = [stream[i * 97:i * 97 + 48].tolist() for i in range(c)]
        beng = BatchedServeEngine(model, params, n_slots=c, cache_window=256)
        beng.warm([48])
        # throwaway serve: the per-width decode/peek jit compiles land here,
        # not in the first measured cell's modeled timeline
        with FleetServer(beng, retrievers[0][1], rcfg, enc) as w:
            w.serve(prompts)
        for rname, retr in retrievers:
            base = run_requests(KNNLMSeq(seq_eng, retr, rcfg, enc), prompts)
            for mode in FLEET_MODES:
                cls = (ContinuousFleetServer if mode == "continuous"
                       else FleetServer)
                with cls(beng, retr, acfg if mode == "async" else rcfg,
                         enc) as srv:
                    fr = (srv.serve(as_requests(prompts))
                          if mode == "continuous" else srv.serve(prompts))
                match = [tuple(r.tokens) for r in fr.results] == base["tokens"]
                assert match, f"{rname}/{mode}/c{c}: token streams diverged"
                cell = dict(
                    seq_modeled_s=base["analytic"],
                    fleet_modeled_s=fr.analytic_time,
                    modeled_speedup=(base["analytic"]
                                     / max(fr.analytic_time, 1e-9)),
                    tokps_modeled=fr.throughput(),
                    tokps_wall=fr.throughput(modeled=False),
                    tokens=sum(len(r.tokens) for r in fr.results),
                    kb_calls=fr.kb_calls, rounds=fr.rounds,
                    outputs_token_match=match)
                results[rname][mode][str(c)] = cell
                print(f"fleet/{rname}/{mode}/c{c}: "
                      f"seq {cell['seq_modeled_s']:.3f}s -> "
                      f"{cell['fleet_modeled_s']:.3f}s modeled "
                      f"({cell['modeled_speedup']:.2f}x), "
                      f"{cell['kb_calls']} KB calls / {cell['rounds']} rounds, "
                      f"token-match={match}")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--mode", choices=("fig5", "fleet"), default="fig5",
                    help="fig5: single-request k-sweep (CSV rows); fleet: "
                         "seq-vs-fleet/continuous/async concurrency sweep "
                         "(BENCH_knnlm_fleet.json)")
    from repro.retrieval.backends import BACKENDS
    ap.add_argument("--backend", choices=list(BACKENDS),
                    default="numpy",
                    help="EDR datastore-scan backend (repro.retrieval."
                         "backends; int8* variants are inexact/quantized)")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard count for the sharded backends (0 = one "
                         "shard per visible device; N > 1 on CPU forces an "
                         "N-device host platform before jax initializes)")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--ks", default="1,8,64",
                    help="comma-separated neighbour counts (fig5 mode)")
    ap.add_argument("--concurrency", default="1,2,4",
                    help="comma-separated fleet widths (fleet mode; level c "
                         "serves c requests through c slots)")
    ap.add_argument("--k", type=int, default=8,
                    help="neighbour count for the fleet sweep")
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--stride", type=int, default=3)
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    if args.mode == "fleet":
        results = run_fleet(
            concurrency=tuple(int(x) for x in args.concurrency.split(",")),
            backend=args.backend, mesh_shards=args.mesh_shards, k=args.k,
            max_new=args.max_new, stride=args.stride)
        if args.json is not None:
            write_json("knnlm_fleet", {
                "config": dict(concurrency=args.concurrency, k=args.k,
                               max_new=args.max_new, stride=args.stride,
                               backend=args.backend,
                               mesh_shards=args.mesh_shards, tiny=args.tiny),
                "results": results}, args.json)
        sys.exit(0)
    rows = run(n_requests=args.requests,
               ks=tuple(int(x) for x in args.ks.split(",")),
               backend=args.backend, mesh_shards=args.mesh_shards)
    if args.json is not None:
        write_json("knnlm", {
            "config": dict(requests=args.requests, ks=args.ks,
                           backend=args.backend,
                           mesh_shards=args.mesh_shards, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)
