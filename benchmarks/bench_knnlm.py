"""Paper Figure 5: KNN-LM serving speed-ups (per-token retrieval; spatial-prefetch
cache + token-match verification), k in {1, 8, 64}, fixed stride vs OS^3.

``--backend`` routes the EDR datastore scan through the retrieval-backend
layer (numpy / kernel / sharded); ``--mesh-shards N`` forces an N-device host
platform for the sharded backend (applied before jax loads, like
launch/serve.py)."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.retrieval.backends import bootstrap_mesh_shards  # noqa: E402

bootstrap_mesh_shards()                 # before anything imports jax

import dataclasses  # noqa: E402

import jax  # noqa: E402

from benchmarks.common import (VOCAB, add_json_arg, add_tiny_arg,  # noqa: E402
                               apply_tiny, csv_row, knn_stack, rows_to_json,
                               run_requests, speedup_pair, write_json)
from repro.configs import RaLMConfig, get_config, reduced  # noqa: E402
from repro.core.knnlm import KNNLMSeq, KNNLMSpec  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.retrieval.retrievers import (ExactDenseRetriever,  # noqa: E402
                                        IVFRetriever)
from repro.serving.engine import ServeEngine  # noqa: E402


def run(n_requests: int = 3, ks=(1, 8, 64), backend: str = "numpy",
        mesh_shards: int = 0) -> list:
    """``backend`` picks the EDR datastore-scan backend
    (repro.retrieval.backends.BACKENDS, int8 quantized included);
    ``mesh_shards`` caps the sharded shard count (0 = one shard per visible
    device)."""
    rows = []
    cfg = reduced(get_config("knnlm-247m"), layers=2, d_model=128, vocab=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream, enc, ds = knn_stack()
    prompts = [stream[i * 97:i * 97 + 48].tolist() for i in range(n_requests)]
    edr = ExactDenseRetriever(ds, backend=backend, mesh_shards=mesh_shards)
    if backend != "numpy":
        detail = (f"{edr.backend.n_shards} shard(s)"
                  if edr.backend.name.endswith("sharded")
                  else "device-resident KB")
        print(f"EDR datastore backend: {edr.backend.name} ({detail})")
    for rname, retr in [("edr", edr),
                        ("adr", IVFRetriever(ds, n_clusters=128, nprobe=4,
                                             iters=3))]:
        for k in ks:
            base_cfg = RaLMConfig(knnlm=True, knn_k=k, max_new_tokens=48,
                                  speculation_stride=3)
            eng = ServeEngine(model, params, cache_window=256)
            b = run_requests(KNNLMSeq(eng, retr, base_cfg, enc), prompts)
            for label, rc in [("s3", base_cfg),
                              ("OS3", dataclasses.replace(base_cfg, use_os3=True))]:
                a = run_requests(KNNLMSpec(eng, retr, rc, enc), prompts)
                rows.append(csv_row(
                    f"fig5/{rname}/k{k}/{label}", 1e6 * a["analytic"] / a["n"],
                    f"{speedup_pair(b, a)} "
                    f"preserved={a['tokens'] == b['tokens']} "
                    f"mism={a['mismatches']}"))
                print(rows[-1])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(allow_abbrev=False)
    from repro.retrieval.backends import BACKENDS
    ap.add_argument("--backend", choices=list(BACKENDS),
                    default="numpy",
                    help="EDR datastore-scan backend (repro.retrieval."
                         "backends; int8* variants are inexact/quantized)")
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard count for the sharded backends (0 = one "
                         "shard per visible device; N > 1 on CPU forces an "
                         "N-device host platform before jax initializes)")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--ks", default="1,8,64",
                    help="comma-separated neighbour counts")
    add_tiny_arg(ap)
    add_json_arg(ap)
    args = ap.parse_args()
    apply_tiny(args)
    rows = run(n_requests=args.requests,
               ks=tuple(int(x) for x in args.ks.split(",")),
               backend=args.backend, mesh_shards=args.mesh_shards)
    if args.json is not None:
        write_json("knnlm", {
            "config": dict(requests=args.requests, ks=args.ks,
                           backend=args.backend,
                           mesh_shards=args.mesh_shards, tiny=args.tiny),
            "rows": rows_to_json(rows)}, args.json)
