"""Paper Figure 5: KNN-LM serving speed-ups (per-token retrieval; spatial-prefetch
cache + token-match verification), k in {1, 8, 64}, fixed stride vs OS^3."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import VOCAB, csv_row, knn_stack, run_requests, speedup_pair
from repro.configs import RaLMConfig, get_config, reduced
from repro.core.knnlm import KNNLMSeq, KNNLMSpec
from repro.models.model import build_model
from repro.retrieval.retrievers import ExactDenseRetriever, IVFRetriever
from repro.serving.engine import ServeEngine


def run(n_requests: int = 3, ks=(1, 8, 64)) -> list:
    rows = []
    cfg = reduced(get_config("knnlm-247m"), layers=2, d_model=128, vocab=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream, enc, ds = knn_stack()
    prompts = [stream[i * 97:i * 97 + 48].tolist() for i in range(n_requests)]
    for rname, retr in [("edr", ExactDenseRetriever(ds)),
                        ("adr", IVFRetriever(ds, n_clusters=128, nprobe=4,
                                             iters=3))]:
        for k in ks:
            base_cfg = RaLMConfig(knnlm=True, knn_k=k, max_new_tokens=48,
                                  speculation_stride=3)
            eng = ServeEngine(model, params, cache_window=256)
            b = run_requests(KNNLMSeq(eng, retr, base_cfg, enc), prompts)
            for label, rc in [("s3", base_cfg),
                              ("OS3", dataclasses.replace(base_cfg, use_os3=True))]:
                a = run_requests(KNNLMSpec(eng, retr, rc, enc), prompts)
                rows.append(csv_row(
                    f"fig5/{rname}/k{k}/{label}", 1e6 * a["analytic"] / a["n"],
                    f"{speedup_pair(b, a)} "
                    f"preserved={a['tokens'] == b['tokens']} "
                    f"mism={a['mismatches']}"))
                print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
