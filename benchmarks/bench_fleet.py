"""Fleet serving benchmark: throughput and per-request latency vs concurrency.

    PYTHONPATH=src python benchmarks/bench_fleet.py --retriever edr \
        --concurrency 1,2,4 --requests 4 --max-new 32

For each retriever (EDR/ADR/SR) and each concurrency level c, serves the same
request set through a c-slot BatchedServeEngine + FleetServer and reports:

  * tokens/s on the MODELED timeline (the paper's §A.1 batched-retrieval
    latency shape — near-constant batch cost for EDR/SR, linear-with-intercept
    for ADR). Cross-request batched verification amortizes the per-round KB
    call across slots, so modeled throughput rises with c — steeply for
    EDR/SR, shallowly for ADR (its per-query intercept survives batching).
  * tokens/s on the wall clock of this (1-core) container, where batched
    retrieval is compute-bound and the gain comes only from fewer call
    overheads — reported alongside, as everywhere else in benchmarks/.
  * per-request latency (the shared lockstep timeline) and KB calls per token.

c = 1 uses the same fleet machinery with one slot, so the comparison isolates
the cross-request amortization rather than engine differences.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig  # noqa: E402
from repro.launch.serve import build_stack, make_server  # noqa: E402
from repro.training.data import make_queries  # noqa: E402

from common import add_json_arg, warm_engine, write_json  # noqa: E402


def bench_one(retr_name: str, levels, n_requests: int, max_new: int,
              n_docs: int, stride: int):
    stack = build_stack(retr_name, n_docs=n_docs,
                        rcfg=RaLMConfig(max_new_tokens=max_new,
                                        speculation_stride=stride))
    rcfg = stack.rcfg
    prompts = [(q * 12)[:48] for q in make_queries(stack.docs, n_requests)]
    print(f"\n== {retr_name.upper()}  ({n_docs} docs, {n_requests} requests, "
          f"max_new={max_new}, s={stride}) ==")
    print(f"{'conc':>4} {'tok/s (modeled)':>16} {'tok/s (wall)':>13} "
          f"{'latency (modeled)':>18} {'kb_calls':>9} {'q/call':>7}")
    base = None
    rows = []
    for c in levels:
        tot_an = tot_w = 0.0
        n_tok = calls = queries = 0
        with make_server(stack, scheduler="fixed", n_slots=c) as fleet:
            warm_engine(fleet.engine, rcfg)
            fleet.serve(prompts[:c])             # warmup: jit + stats calibration
            for i in range(0, len(prompts), c):
                fr = fleet.serve(prompts[i:i + c])
                tot_an += fr.analytic_time
                tot_w += fr.wall_time
                n_tok += fr.total_tokens
                calls += fr.kb_calls
                queries += fr.kb_queries
        tp_m = n_tok / max(tot_an, 1e-9)
        tp_w = n_tok / max(tot_w, 1e-9)
        lat = tot_an / max(-(-len(prompts) // c), 1)
        print(f"{c:>4} {tp_m:>16.1f} {tp_w:>13.1f} {lat:>17.3f}s "
              f"{calls:>9} {queries / max(calls, 1):>7.1f}")
        rows.append(dict(concurrency=c, tokps_modeled=tp_m, tokps_wall=tp_w,
                         latency_modeled_s=lat, kb_calls=calls,
                         kb_queries=queries))
        if base is None:
            base = tp_m
    best = max(r["tokps_modeled"] for r in rows)
    print(f"   modeled-throughput scaling x{best / max(base, 1e-9):.2f} "
          f"(c={levels[0]} -> best)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", default="edr",
                    help="edr | adr | sr | all")
    ap.add_argument("--concurrency", default="1,2,4",
                    help="comma-separated fleet sizes")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    add_json_arg(ap)
    args = ap.parse_args()
    levels = [int(x) for x in args.concurrency.split(",")]
    names = ["edr", "adr", "sr"] if args.retriever == "all" else [args.retriever]
    results = {}
    for name in names:
        results[name] = bench_one(name, levels, args.requests, args.max_new,
                                  args.n_docs, args.stride)
    if args.json is not None:
        write_json("fleet", {
            "config": dict(concurrency=levels, requests=args.requests,
                           max_new=args.max_new, n_docs=args.n_docs,
                           stride=args.stride),
            "results": results}, args.json)


if __name__ == "__main__":
    main()
