"""Backend scan latency: flat numpy vs Pallas kernel vs sharded mesh.

    PYTHONPATH=src python benchmarks/bench_backends.py --json

Sweeps the dense top-k scan — the verification hot spot every serving path
funnels into — across KB size and query batch for each execution backend in
`repro.retrieval.backends`. What the cells show:

  * flat    — single-host BLAS matmul + canonical argpartition top-k; latency
              streams the whole (N, d) matrix per call.
  * kernel  — the Pallas blocked top-k. On TPU this is the fused MXU scan; on
              CPU the kernel body only runs under the (slow, semantics-only)
              interpreter, so off-TPU the bench routes it through the jnp
              oracle (`force_ref`) by default — same program shape, honest
              wall numbers (`--kernel-interpret` forces the interpreter).
  * sharded — the KB sharded over the visible devices (`--mesh-shards`, on
              CPU forcing a simulated multi-device host platform): per-shard
              scan + ONE all-gather per call. On a single physical core the
              shards time-slice, so expect parity, not speed-up — the point
              on this box is that the collective program is the same one a
              real mesh runs, and its latency is one scan + O(shards*B*k)
              collective volume.

Each strategy's **int8 quantized sibling** (int8 / int8-kernel /
int8-sharded) runs in the same sweep: the KB held as per-row symmetric int8
codes + fp32 scales, ~4x less index memory. Quantized rows are INEXACT
(`exact` false) — every row records `kb_bytes` (resident index footprint)
and `recall_at_k` measured against the flat fp32 scan on the same queries
(exact backends score 1.0 by construction; the quantized contract is
recall@k >= 0.95, tests/test_quantized.py).

``--retriever`` adds the ADR axis: `adr` (or `both`) times the IVF probe —
host-side centroid scan + the backend-executed gathered bucket scan
(`search_gathered`) — through the SAME backends, the regime where the
paper reports its weakest speedups (1.04–1.39x) and backend efficiency
matters most. Rows carry a `retriever` field either way. ADR rows also
record the probe's candidate width and peak candidate-buffer bytes:
`cand_buf_bytes` is what the backend's gather actually holds (the fused
kernel/sharded paths tile the gather to one (B, block_c) slab, so it is
independent of C) vs `cand_buf_bytes_pregathered`, the (B, C, ...) slab a
pre-gathered scan materializes — the fused path's memory win in numbers.

Per cell: median seconds over --repeats (first call per shape excluded — it
pays the XLA compile), and µs/query. ``--json`` emits BENCH_backends.json via
the shared benchmarks/common.py flag.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.retrieval.backends import bootstrap_mesh_shards  # noqa: E402

bootstrap_mesh_shards()                 # before common.py imports jax

import argparse  # noqa: E402

import numpy as np  # noqa: E402

from common import add_json_arg, measure_wall, write_json  # noqa: E402


def run(kb_sizes, batches, k, dim, repeats, mesh_shards, kernel_interpret,
        retriever="edr", n_clusters=64, nprobe=4, block_c=None):
    import jax

    from repro.retrieval.backends import make_backend
    from repro.retrieval.kb import DenseKB
    from repro.retrieval.retrievers import IVFRetriever, RetrieverStats

    def ivf_with_backend(proto, backend):
        """Same IVF index (shared clustering — Lloyd runs once per KB size),
        different execution backend; the __new__ pattern common._cached_ivf
        uses."""
        r = IVFRetriever.__new__(IVFRetriever)
        r.kb, r.nprobe = proto.kb, proto.nprobe
        r.centroids, r.buckets = proto.centroids, proto.buckets
        r._bucket_pad, r._bucket_len = proto._bucket_pad, proto._bucket_len
        r.stats = RetrieverStats("linear_intercept")
        r.backend = backend
        return r
    def recall_at_k(ids, ref_ids):
        """Fraction of the fp32 reference's real top-k ids the backend
        recovered, averaged over the batch (pad slots id=-1 excluded)."""
        hits = []
        for row, ref in zip(np.asarray(ids), np.asarray(ref_ids)):
            want = set(int(i) for i in ref if i >= 0)
            if not want:
                continue
            got = set(int(i) for i in row if i >= 0)
            hits.append(len(got & want) / len(want))
        return float(np.mean(hits)) if hits else 1.0

    rng = np.random.default_rng(0)
    on_tpu = jax.default_backend() == "tpu"
    force_ref = not on_tpu and not kernel_interpret
    rows = []
    built_shards = None                 # what ShardedBackend actually ran with
    print(f"{'retr':4s} {'backend':13s} {'n_docs':>8s} {'batch':>6s} "
          f"{'seconds':>10s} {'us/query':>10s} {'recall':>7s} {'kb_MB':>7s}")
    for n in kb_sizes:
        emb = rng.standard_normal((n, dim)).astype(np.float32)
        emb /= np.linalg.norm(emb, axis=1, keepdims=True)
        backends = [
            make_backend("numpy", emb, block_c=block_c),
            make_backend("kernel", emb, force_ref=force_ref, block_c=block_c),
            make_backend("sharded", emb, n_shards=mesh_shards or None,
                         block_c=block_c),
            make_backend("int8", emb, block_c=block_c),
            make_backend("int8-kernel", emb, force_ref=force_ref,
                         block_c=block_c),
            make_backend("int8-sharded", emb, n_shards=mesh_shards or None,
                         block_c=block_c),
        ]
        built_shards = backends[2].n_shards     # may be < --mesh-shards
        # (backend, axis, call, ivf-or-None) — call -> (ids, scores)
        scans = []
        ref_call = {}                   # axis -> the flat fp32 reference scan
        proto = None                    # IVF clustering, built once per KB
        for b in backends:
            if retriever in ("edr", "both"):
                scans.append((b, "edr",
                              lambda qs, kk, b=b: b.search(qs, kk), None))
                ref_call.setdefault("edr", scans[-1][2])
            if retriever in ("adr", "both"):
                # ONE clustering per KB size, shared across backends: the
                # cell times the probe — host centroid scan +
                # backend-executed gathered bucket scan
                if proto is None:
                    proto = IVFRetriever(DenseKB(embeddings=emb, docs=[[]] * n),
                                         n_clusters=min(n_clusters, n),
                                         nprobe=nprobe, backend=b)
                    r = proto
                else:
                    r = ivf_with_backend(proto, b)
                scans.append((b, "adr",
                              lambda qs, kk, r=r: r.retrieve(qs, kk), r))
                ref_call.setdefault("adr", scans[-1][2])
        for B in batches:
            qs = rng.standard_normal((B, dim)).astype(np.float32)
            for b, axis, call, ivf in scans:
                rec = recall_at_k(call(qs, k)[0], ref_call[axis](qs, k)[0])
                sec, _, _ = measure_wall(lambda: call(qs, k),
                                         repeats=repeats, warmup=1)
                row = dict(backend=b.name, retriever=axis, n_docs=n,
                           batch=B, seconds=sec,
                           us_per_query=sec / B * 1e6,
                           exact=bool(b.exact),
                           recall_at_k=rec, kb_bytes=int(b.kb_bytes))
                if ivf is not None:
                    # peak candidate-buffer bytes for this cell's probe: what
                    # the backend's gather actually holds (fused paths: one
                    # (B, block_c) tile) vs the (B, C, ...) a pre-gathered
                    # scan materializes
                    C = ivf._cand_width(k)
                    row.update(
                        cand_width=int(C),
                        cand_buf_bytes=int(b.gathered_scratch_bytes(B, C)),
                        cand_buf_bytes_pregathered=int(
                            b.pregathered_scratch_bytes(B, C)))
                rows.append(row)
                print(f"{axis:4s} {b.name:13s} {n:8d} {B:6d} {sec:10.5f} "
                      f"{sec / B * 1e6:10.1f} {rec:7.3f} "
                      f"{b.kb_bytes / 1e6:7.2f}")
    return rows, dict(k=k, dim=dim, repeats=repeats,
                      retriever=retriever, n_clusters=n_clusters,
                      nprobe=nprobe, block_c=block_c,
                      devices=len(jax.devices()),
                      mesh_shards=built_shards,
                      kernel_mode=("pallas" if on_tpu or kernel_interpret
                                   else "jnp-ref"))


def main():
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--kb-sizes", default="4096,16384,65536",
                    help="comma-separated KB sizes (docs)")
    ap.add_argument("--batches", default="1,8,32",
                    help="comma-separated query batch sizes")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--mesh-shards", type=int, default=0,
                    help="shard count for the sharded backend (0 = all "
                         "visible devices; N > 1 on CPU forces an N-device "
                         "host platform before jax initializes)")
    ap.add_argument("--kernel-interpret", action="store_true",
                    help="off-TPU, time the Pallas interpreter instead of "
                         "the jnp oracle (slow; semantics-only)")
    ap.add_argument("--retriever", choices=["edr", "adr", "both"],
                    default="edr",
                    help="which scan to time: edr (full dense top-k), adr "
                         "(the IVF probe via search_gathered), or both")
    ap.add_argument("--n-clusters", type=int, default=64,
                    help="ADR axis: IVF cluster count (clamped to the KB size)")
    ap.add_argument("--nprobe", type=int, default=4,
                    help="ADR axis: probed clusters per query")
    ap.add_argument("--block-c", type=int, default=0,
                    help="fused-gather tile width for the kernel/sharded "
                         "families (0 = kernels.dense_topk.FUSED_BLOCK_C); "
                         "sets the ADR cells' peak candidate-buffer bytes")
    add_json_arg(ap)
    args = ap.parse_args()
    rows, meta = run([int(x) for x in args.kb_sizes.split(",")],
                     [int(x) for x in args.batches.split(",")],
                     args.k, args.dim, args.repeats, args.mesh_shards,
                     args.kernel_interpret, args.retriever,
                     args.n_clusters, args.nprobe,
                     block_c=args.block_c or None)
    if args.json is not None:
        write_json("backends", {"config": meta, "rows": rows}, args.json)


if __name__ == "__main__":
    main()
