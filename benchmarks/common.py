"""Shared benchmark infrastructure.

Corpus/KB/datastore builders are disk-cached (.bench_cache/) so the six paper-table
benchmarks share one corpus build. Sizes are chosen so the retriever-vs-LM latency
*ratios* land in the paper's regimes on CPU:

  EDR — flat scan over a large embedding matrix (memory-bound stream) >= one LM
        generation stride  -> big speed-up headroom (paper: 1.75-2.39x),
  ADR — IVF probe ~ small fraction of a stride -> fixed s=3 can regress, OS3 rescues
        (paper: 0.58-1.39x),
  SR  — BM25 over term arrays, between the two (paper: 0.97-1.77x).
"""
from __future__ import annotations

import json
import os
import pickle
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig, get_config, reduced  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.retrieval.encoder import ContextEncoder  # noqa: E402
from repro.retrieval.kb import DenseKB, SparseKB, build_knn_datastore  # noqa: E402
from repro.retrieval.retrievers import (BM25Retriever, ExactDenseRetriever,  # noqa: E402
                                        IVFRetriever)
from repro.serving.engine import ServeEngine  # noqa: E402
from repro.training.data import make_queries, synthetic_corpus  # noqa: E402

CACHE = os.path.join(os.path.dirname(__file__), "..", ".bench_cache")
ENC_DIM = 512   # 400k x 512 f32 -> ~800MB stream per exact-dense call
N_DOCS_DENSE = 400_000
N_DOCS_SPARSE = 30_000
KNN_ENTRIES = 1_000_000
KNN_DIM = 128
VOCAB = 50257   # gpt2-medium class host LM


def _cached(name, builder):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, name + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = builder()
    with open(path, "wb") as f:
        pickle.dump(obj, f)
    return obj


def host_lm(seed: int = 0):
    cfg = reduced(get_config("ralm-gpt2-medium"), layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def dense_stack():
    def build():
        docs = synthetic_corpus(N_DOCS_DENSE, VOCAB)
        enc = ContextEncoder(VOCAB, d=ENC_DIM)
        emb = np.stack([enc.encode_doc(d) for d in docs])
        return docs, emb
    docs, emb = _cached(f"dense_{N_DOCS_DENSE}_{ENC_DIM}", build)
    enc = ContextEncoder(VOCAB, d=ENC_DIM)
    return docs, enc, DenseKB(embeddings=emb, docs=docs)


def sparse_stack():
    def build():
        docs = synthetic_corpus(N_DOCS_SPARSE, VOCAB, seed=9)
        kb = SparseKB.build(docs)
        return docs, kb
    docs, kb = _cached(f"sparse_{N_DOCS_SPARSE}", build)
    return docs, ContextEncoder(VOCAB, d=ENC_DIM), kb


def knn_stack():
    def build():
        docs = synthetic_corpus(KNN_ENTRIES // 40, VOCAB, seed=21)
        stream = np.concatenate([np.asarray(d, np.int32) for d in docs])
        enc = ContextEncoder(VOCAB, d=KNN_DIM, window=16)
        ds = build_knn_datastore(stream, enc, context=16, limit=KNN_ENTRIES)
        return stream, ds
    stream, ds = _cached(f"knn_{KNN_ENTRIES}_{KNN_DIM}", build)
    return stream, ContextEncoder(VOCAB, d=KNN_DIM, window=16), ds


def make_retriever(name: str):
    if name == "edr":
        docs, enc, kb = dense_stack()
        return docs, enc, ExactDenseRetriever(kb)
    if name == "adr":
        docs, enc, kb = dense_stack()
        return docs, enc, _cached_ivf(kb, docs)
    if name == "sr":
        docs, enc, kb = sparse_stack()
        return docs, enc, BM25Retriever(kb)
    raise KeyError(name)


def _cached_ivf(kb, docs):
    def build():
        r = IVFRetriever(kb, n_clusters=256, nprobe=2, iters=4)
        return r.centroids, r.buckets
    cents, buckets = _cached(f"ivf_{kb.size}", build)
    r = IVFRetriever.__new__(IVFRetriever)
    r.kb = kb
    r.nprobe = 2
    r.centroids = cents
    r.buckets = buckets
    from repro.retrieval.retrievers import RetrieverStats
    r.stats = RetrieverStats("linear_intercept")
    return r


def bench_prompts(docs, n: int, seed: int = 3):
    # exactly 48 tokens: prompts must sit on the warmed jit shape grid (a single
    # off-grid prompt charges an XLA compile to whichever server runs first)
    return [(q * 32)[:48] for q in make_queries(docs, n, seed=seed)]


def warm_engine(eng, rcfg, prompt_len: int = 48, chunk_len: int = 64) -> None:
    """Compile every prefill shape the serving grid can hit (doc chunk + prompt +
    i*generation_stride, plus the doc-less initial prefill)."""
    grid = [prompt_len + i * rcfg.generation_stride
            for i in range(rcfg.max_new_tokens // rcfg.generation_stride + 1)]
    eng.warm(grid + [chunk_len + g for g in grid])


def run_requests(server, prompts, warmup: int = 1):
    """-> dict of aggregate latencies. Warmup request amortizes jit compiles."""
    warm_engine(server.engine, server.rcfg)
    for p in prompts[:warmup]:
        server.serve(p)
    agg = dict(wall=0.0, analytic=0.0, gen=0.0, retr=0.0, kb_calls=0,
               kb_queries=0, mismatches=0, rounds=0, tokens=[])
    for p in prompts:
        r = server.serve(p)
        agg["wall"] += r.wall_time
        agg["analytic"] += r.analytic_time
        agg["gen"] += r.gen_time
        agg["retr"] += r.retrieval_time
        agg["kb_calls"] += r.kb_calls
        agg["kb_queries"] += r.kb_queries
        agg["mismatches"] += r.mismatches
        agg["rounds"] += r.rounds
        agg["tokens"].append(tuple(r.tokens))
    agg["n"] = len(prompts)
    return agg


def measure_wall(fn, *, repeats: int = 3, warmup: int = 1):
    """Monotonic-clock wall timing with warmup discard: runs ``fn`` ``warmup``
    times untimed (jit compiles, cache fills), then ``repeats`` timed times,
    and returns ``(median_seconds, samples, last_result)``. The median over
    repeats is the committed number everywhere a BENCH_*.json reports wall
    time — single-shot walls on a shared 1-core container are too noisy to
    gate on."""
    for _ in range(max(0, warmup)):
        fn()
    samples = []
    result = None
    for _ in range(max(1, repeats)):
        t0 = time.monotonic()
        result = fn()
        samples.append(time.monotonic() - t0)
    return float(np.median(samples)), samples, result


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def add_tiny_arg(ap) -> None:
    """Shared smoke-test flag: ``--tiny`` shrinks the module-level corpus /
    datastore sizes so every bench runs end to end in seconds (the CI
    bench-smoke job). Numbers from a tiny run are NOT paper-comparable —
    it exists to keep the BENCH_*.json producers from silently rotting."""
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test sizes: tiny shared corpora/stacks "
                         "(schema checks only; timings not comparable)")


def apply_tiny(args) -> None:
    """Apply ``--tiny`` by rebinding the stack-size globals the builders read
    at call time (cache keys include the sizes, so tiny and full stacks never
    collide in .bench_cache)."""
    global N_DOCS_DENSE, N_DOCS_SPARSE, KNN_ENTRIES, KNN_DIM, ENC_DIM
    if getattr(args, "tiny", False):
        N_DOCS_DENSE, N_DOCS_SPARSE = 1500, 600
        KNN_ENTRIES, KNN_DIM, ENC_DIM = 3000, 32, 64


def rows_to_json(rows) -> list:
    """csv_row strings -> JSON row dicts (name, us_per_call, derived)."""
    out = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        out.append(dict(name=name, us_per_call=float(us), derived=derived))
    return out


def add_json_arg(ap) -> None:
    """Shared machine-readable-output flag: ``--json`` writes the benchmark's
    results to ``BENCH_<name>.json`` at the repo root (or to an explicit
    ``--json PATH``), so successive PRs can track the perf trajectory."""
    ap.add_argument("--json", nargs="?", const="", default=None, metavar="PATH",
                    help="write machine-readable results (default path: "
                         "BENCH_<bench>.json at the repo root)")


def write_json(bench: str, payload: dict, path: str = "") -> str:
    """Emit ``payload`` (plus the bench name) as stable, sorted JSON."""
    out = path or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", f"BENCH_{bench}.json"))
    with open(out, "w") as f:
        json.dump({"bench": bench, **payload}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
    return out


def variant_rcfg(variant: str, **kw) -> RaLMConfig:
    base = dict(max_new_tokens=48, speculation_stride=3, generation_stride=4)
    base.update(kw)
    return RaLMConfig(
        prefetch_top_k=20 if "p" in variant else 1,
        use_os3="s" in variant,
        async_verification="a" in variant,
        **base,
    )


def speedup_pair(base, agg) -> str:
    """Both timelines, each self-consistent: wall vs wall (this 1-core container)
    and modeled vs modeled (paper-hardware batched-retrieval shape, §A.1)."""
    w = base["wall"] / max(agg["wall"], 1e-9)
    m = base["analytic"] / max(agg["analytic"], 1e-9)
    return f"wall={w:.2f}x modeled={m:.2f}x"
