"""§Perf hillclimbing driver: re-lower + re-analyse a single (arch x shape) pair
under explicit optimization overrides, printing the three roofline terms so each
hypothesis -> change -> measure cycle is one invocation.

    PYTHONPATH=src python -m benchmarks.hillclimb kimi-k2-1t-a32b decode_32k \
        kv_shard=head_dim
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_one(arch: str, shape: str, overrides: dict, label: str = "") -> dict:
    from benchmarks.roofline import analyze
    from repro.launch.dryrun import dryrun_pair
    rec = dryrun_pair(arch, shape, verbose=False, **overrides)
    if not rec["ok"]:
        print(f"[FAIL] {label or overrides}: {rec['error']}")
        return rec
    row = analyze([rec])[0]
    row["overrides"] = overrides
    row["label"] = label
    print(f"[{label or 'baseline':28s}] comp={row['t_compute_s']:.3e}s "
          f"mem={row['t_memory_s']:.3e}s coll={row['t_collective_s']:.3e}s "
          f"dominant={row['dominant']} arg={row['arg_gb_per_chip']:.2f}GB "
          f"temp={row['temp_gb_per_chip']:.2f}GB "
          f"coll_bytes={row['coll_bytes_per_chip']:.3g}")
    return row


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    overrides = {}
    for kv in sys.argv[3:]:
        k, v = kv.split("=")
        overrides[k] = int(v) if v.isdigit() else (v == "True" if v in
                                                   ("True", "False") else v)
    run_one(arch, shape, overrides, label=",".join(sys.argv[3:]) or "baseline")


if __name__ == "__main__":
    main()
