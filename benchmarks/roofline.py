"""Roofline analysis (deliverable g): three terms per (arch x shape) from the
single-pod dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * 197e12)
  memory term     = HLO_bytes / (chips * 819e9)
  collective term = collective_bytes / (chips * 50e9)

cost_analysis() on XLA:CPU reports the while-loop body ONCE (scan-rolled layer
stacks, microbatch loops), so HLO_FLOPs underestimates; we therefore also derive
ANALYTIC model FLOPs (6*N*D dense / 6*N_active*D MoE, x3 for the backward pass in
training) and report both plus their ratio. The compute term uses
max(HLO, analytic); the dominant-term call and the §Perf iterations read from this
table.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import LONG_CONTEXT_WINDOW, SHAPES, get_config  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS for the whole step (global, all chips)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens        # fwd 2ND + bwd 4ND
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: ONE token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(records: list) -> list:
    out = []
    for r in records:
        if not r.get("ok"):
            out.append(dict(r, dominant="FAILED"))
            continue
        chips = CHIPS[r["mesh"]]
        hlo_flops = max(r.get("flops", 0.0), 0.0)          # per-device (XLA:CPU)
        mflops = model_flops(r["arch"], r["shape"])
        flops_per_chip = max(hlo_flops, mflops / chips)
        t_comp = flops_per_chip / PEAK_FLOPS_BF16
        # memory proxy: one pass over the buffer assignment (args + outputs +
        # temps). XLA:CPU's "bytes accessed" sums operand bytes over every op
        # including parameter re-declarations in nested computations (~10x
        # inflation measured on kimi decode), so the allocation-based proxy is
        # the stable comparator across §Perf iterations.
        memd = r.get("memory", {})
        arg_bytes = memd.get("argument_bytes", 0)
        bytes_per_chip = float(arg_bytes + memd.get("output_bytes", 0)
                               + memd.get("temp_bytes", 0))
        t_mem = bytes_per_chip / HBM_BW
        coll = r.get("collectives", {}).get("total_bytes", 0.0)
        t_coll = float(coll) / ICI_BW           # census is per-device program
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops": mflops,
            "hlo_flops_per_chip": hlo_flops,
            "useful_ratio": (mflops / chips) / hlo_flops if hlo_flops > 0 else None,
            "mem_bytes_per_chip": bytes_per_chip,
            "coll_bytes_per_chip": coll,
            "arg_gb_per_chip": arg_bytes / 1e9,
            "temp_gb_per_chip": r.get("memory", {}).get("temp_bytes", 0) / 1e9,
        })
    return out


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful FLOP ratio | arg GB/chip | temp GB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        if r.get("dominant") == "FAILED":
            body += f"| {r['arch']} | {r['shape']} | — | — | — | FAILED | | | |\n"
            continue
        ur = r["useful_ratio"]
        body += (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
                 f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
                 f"**{r['dominant']}** | "
                 f"{('%.2f' % ur) if ur else 'n/a'} | "
                 f"{r['arg_gb_per_chip']:.2f} | {r['temp_gb_per_chip']:.2f} |\n")
    return hdr + body


def run(path: str = None, emit_csv: bool = True) -> list:
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "dryrun_single_pod.json")
    if not os.path.exists(path):
        print(f"roofline: no dry-run artifact at {path} "
              f"(run python -m repro.launch.dryrun --all --out {path})")
        return []
    rows = analyze(json.load(open(path)))
    out = []
    for r in rows:
        if r.get("dominant") == "FAILED":
            continue
        dom_t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        out.append(f"roofline/{r['arch']}/{r['shape']},{dom_t * 1e6:.1f},"
                   f"dominant={r['dominant']} comp={r['t_compute_s']:.2e} "
                   f"mem={r['t_memory_s']:.2e} coll={r['t_collective_s']:.2e}")
        if emit_csv:
            print(out[-1])
    return out


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else None)
