"""Fault-tolerance benchmark: goodput and tail latency vs injected fault rate.

    PYTHONPATH=src python benchmarks/bench_faults.py --retriever edr \
        --slots 4 --requests 12 --max-new 32 --rates 0,0.05,0.2

For each fault rate F, the same saturated request set is served through
ContinuousFleetServer while the seeded chaos harness (repro.retrieval.faults)
injects TransientRetrievalError at probability F and latency spikes at
probability F (spikes long enough to trip the per-call deadline) into every
KB scan. The retry/backoff/deadline shell (``--retry-max``,
``--retrieval-timeout``) absorbs the transient faults — KB search is
deterministic, so a retried call returns byte-identical rows — and rounds
whose merged call fails every attempt degrade to speculation-only instead of
killing the stream.

Reported per rate: modeled p50/p99 request latency, total modeled throughput,
GOODPUT (tokens of non-degraded requests over the makespan — the service the
fleet delivered at full fidelity), the fault ledger (retried errors/timeouts,
calls failed for good, degraded/shed requests), and ``outputs_match`` — every
non-degraded request's tokens byte-identical to the clean (fault-free)
reference run, asserted, which is the preservation claim under chaos.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import RaLMConfig  # noqa: E402
from repro.launch.serve import build_stack, make_server  # noqa: E402
from repro.retrieval.faults import FaultSpec, inject_faults  # noqa: E402
from repro.serving.continuous import as_requests  # noqa: E402

from common import add_json_arg, warm_engine, write_json  # noqa: E402


def bench_one(retr_name: str, rates, args):
    stack = build_stack(
        retr_name, n_docs=args.n_docs,
        rcfg=RaLMConfig(max_new_tokens=args.max_new,
                        speculation_stride=args.stride,
                        retry_max=args.retry_max,
                        retrieval_timeout_s=args.retrieval_timeout,
                        max_queue_depth=args.max_queue_depth,
                        queue_deadline_s=args.queue_deadline))
    retr, rcfg = stack.retriever, stack.rcfg
    from repro.training.data import make_queries
    prompts = [(q * 12)[:48] for q in make_queries(stack.docs, args.requests)]
    # the dense/sparse KB execution object the injector wraps in place —
    # saved so each rate starts from the clean stack
    attr = "backend" if hasattr(retr, "backend") else "kb"
    orig = getattr(retr, attr)

    print(f"\n== {retr_name.upper()}  ({args.n_docs} docs, {args.requests} "
          f"requests, {args.slots} slots, retry_max={args.retry_max}, "
          f"deadline={args.retrieval_timeout:g}s, spike={args.spike_s:g}s) ==")
    print(f"{'rate':>6} {'goodput':>9} {'tok/s':>8} {'p50':>7} {'p99':>7} "
          f"{'retried':>8} {'failed':>7} {'degr':>5} {'shed':>5} {'match':>6}")

    rows = []
    with make_server(stack, scheduler="continuous",
                     n_slots=args.slots) as server:
        warm_engine(server.engine, rcfg)
        # clean reference run: jit warmup + the byte-parity baseline every
        # rate's non-degraded outputs are compared against
        ref = server.serve(as_requests(prompts))
        ref_tokens = [r.tokens for r in ref.results]
        for rate in rates:
            inj = None
            if rate > 0:
                inj = inject_faults(retr, FaultSpec(
                    seed=args.seed, p_error=rate, p_spike=rate,
                    spike_s=args.spike_s))
            try:
                cr = server.serve(as_requests(prompts))
            finally:
                setattr(retr, attr, orig)   # unwrap before the next rate
            ok = [r for r in cr.results if r.status == "ok"]
            match = all(r.tokens == ref_tokens[i]
                        for i, r in enumerate(cr.results)
                        if r.status == "ok")
            assert match, f"rate {rate}: a non-degraded output diverged"
            goodput = (sum(len(r.tokens) for r in ok)
                       / max(cr.analytic_time, 1e-9))
            retried = cr.kb_errors + cr.kb_timeouts
            print(f"{rate:>6g} {goodput:>9.1f} {cr.throughput():>8.1f} "
                  f"{cr.p50:>6.2f}s {cr.p99:>6.2f}s {retried:>8} "
                  f"{cr.kb_failures:>7} {cr.degraded_requests:>5} "
                  f"{cr.shed:>5} {str(match):>6}")
            rows.append(dict(
                rate=rate,
                p50_s=cr.p50, p99_s=cr.p99, makespan_s=cr.analytic_time,
                tokps_modeled=cr.throughput(),
                goodput_modeled=goodput,
                tokens_ok=sum(len(r.tokens) for r in ok),
                requests_ok=len(ok),
                degraded=cr.degraded_requests,
                shed=cr.shed,
                retried_errors=cr.kb_errors,
                retried_timeouts=cr.kb_timeouts,
                failed_calls=cr.kb_failures,
                seed_failures=cr.seed_failures,
                worker_crashes=cr.worker_crashes,
                injected=inj.injected if inj else 0,
                outputs_match=match))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--retriever", default="edr", help="edr | adr | sr | all")
    ap.add_argument("--rates", default="0,0.05,0.2",
                    help="comma-separated per-call fault probabilities "
                         "(applied to both errors and latency spikes)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--stride", type=int, default=3)
    ap.add_argument("--retry-max", type=int, default=4)
    ap.add_argument("--retrieval-timeout", type=float, default=0.1,
                    help="per-KB-call deadline; injected spikes overrun it")
    ap.add_argument("--spike-s", type=float, default=0.25,
                    help="injected latency-spike duration (> the deadline)")
    ap.add_argument("--max-queue-depth", type=int, default=0)
    ap.add_argument("--queue-deadline", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=7)
    add_json_arg(ap)
    args = ap.parse_args()
    rates = [float(x) for x in args.rates.split(",")]
    names = ["edr", "adr", "sr"] if args.retriever == "all" else [args.retriever]
    results = {name: bench_one(name, rates, args) for name in names}
    if args.json is not None:
        write_json("faults", {
            "config": dict(rates=rates, slots=args.slots,
                           requests=args.requests, max_new=args.max_new,
                           n_docs=args.n_docs, stride=args.stride,
                           retry_max=args.retry_max,
                           retrieval_timeout_s=args.retrieval_timeout,
                           spike_s=args.spike_s, seed=args.seed),
            "results": results}, args.json)


if __name__ == "__main__":
    main()
